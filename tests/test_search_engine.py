"""SearchEngine: every backend x policy returns the brute-force result set;
auto-selection, stats shape, and the pruning wins of warm-start/best-first."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ref
from repro.core.index import build_index
from repro.search import SearchEngine, SearchStats, available_backends
from tests.conftest import clustered

LOCAL_BACKENDS = ["scan", "kernel", "brute"]   # sharded needs a multi-dev mesh


def _sets_equal(ids, iref):
    return (np.sort(np.asarray(ids), 1) == np.sort(iref, 1)).mean()


def test_registry_has_all_backends():
    assert {"scan", "kernel", "sharded", "brute"} <= set(available_backends())


def test_auto_selection_cpu(rng):
    small = build_index(jnp.asarray(rng.normal(size=(100, 8)).astype(np.float32)),
                        n_pivots=4, block_size=32)
    big = build_index(jnp.asarray(rng.normal(size=(2000, 8)).astype(np.float32)),
                      n_pivots=4, block_size=64)
    assert SearchEngine(small).backend_name == "brute"
    assert SearchEngine(big).backend_name == "scan"   # CPU: no Mosaic


@pytest.mark.parametrize("backend", LOCAL_BACKENDS)
@pytest.mark.parametrize("warm_start,best_first",
                         [(False, False), (True, False), (False, True),
                          (True, True)])
def test_backends_match_brute_random(backend, warm_start, best_first, rng):
    db = rng.normal(size=(900, 24)).astype(np.float32)
    q = rng.normal(size=(17, 24)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=64)
    eng = SearchEngine(idx, backend=backend, warm_start=warm_start,
                       best_first=best_first, bm=8)
    s, i, stats = eng.search(jnp.asarray(q), 7)
    sref, iref = ref.brute_force_knn(q, db, 7)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)
    assert _sets_equal(i, iref) > 0.98                # ties only
    assert isinstance(stats, SearchStats) and stats.backend == backend


def _adversarial(rng, n, d):
    """Tight duplicate-heavy clusters plus a thin uniform background: ties
    and near-ties everywhere a wrong bound, a stale τ seed, or a lossy
    merge would actually change the result set."""
    n_dup = n // 3
    base = clustered(rng, n - n_dup, d, n_centers=4, noise=0.01)
    dup = base[rng.integers(0, len(base), n_dup)] + 1e-4 * rng.normal(
        size=(n_dup, d)).astype(np.float32)
    x = np.concatenate([base, dup])
    return (x / np.linalg.norm(x, axis=1, keepdims=True)).astype(np.float32)


def _fp64_profile(q, db, ids):
    """Exact fp64 similarity profile of a returned id set, sorted desc.

    Two result sets are equivalent top-k answers iff their profiles are
    identical — this is tie-safe where raw id comparison is not."""
    qn = q.astype(np.float64)
    qn /= np.linalg.norm(qn, axis=1, keepdims=True)
    dbn = db.astype(np.float64)
    dbn /= np.linalg.norm(dbn, axis=1, keepdims=True)
    sims = np.einsum("md,mkd->mk", qn, dbn[np.maximum(np.asarray(ids), 0)])
    sims = np.where(np.asarray(ids) >= 0, sims, -np.inf)
    return -np.sort(-sims, axis=1)


@settings(max_examples=10, deadline=None)
@given(st.integers(60, 400), st.integers(3, 24), st.integers(1, 12),
       st.integers(0, 10_000))
def test_cross_backend_equivalence_property(n, d, k, seed):
    """THE cross-backend contract, one property: the same corpus through
    scan / kernel / tree / sharded (flat and per-shard tree) / brute
    returns identical scores and indices (indices compared exactly when
    the fp64 profile is tie-free, by profile equality otherwise).  This
    replaces the old per-backend pairwise checks — any backend diverging
    from any other fails here by transitivity through the fp64 oracle."""
    import jax
    rng = np.random.default_rng(seed)
    kind = seed % 3
    if kind == 0:
        db = rng.normal(size=(n, d)).astype(np.float32)
    elif kind == 1:
        db = clustered(rng, n, d)
    else:
        db = _adversarial(rng, n, d)
    k = min(k, n)
    q = rng.normal(size=(4, d)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=min(4, n), block_size=32)
    sref, iref = ref.brute_force_knn(q, db, k)          # fp64 oracle
    # a query's id set is uniquely determined iff its profile is tie-free
    # and strictly separated from the (k+1)-th best
    if k < n:
        s_next = ref.brute_force_knn(q, db, k + 1)[0][:, -1]
        sep = sref[:, -1] > s_next + 1e-9
    else:
        sep = np.ones(len(q), bool)
    tie_free = sep & (np.diff(sref, axis=1) < -1e-9).all(axis=1) \
        if k > 1 else sep

    mesh = jax.make_mesh((1,), ("data",))
    from repro.core.distributed import build_sharded_index, place_sharded_index
    sidx = place_sharded_index(
        build_sharded_index(db, 1, n_pivots=min(4, n), block_size=32), mesh)
    runs = {
        "brute": SearchEngine(idx, backend="brute"),
        "scan": SearchEngine(idx, backend="scan"),
        "kernel": SearchEngine(idx, backend="kernel", bm=8),
        "tree": SearchEngine(idx, backend="tree", bm=8),
        "sharded": SearchEngine(sidx, mesh=mesh, tree_shards=False),
        "sharded_tree": SearchEngine(sidx, mesh=mesh, tree_shards=True),
    }
    for name, eng in runs.items():
        s, i, _ = eng.search(jnp.asarray(q), k)
        msg = f"{name} n={n} d={d} k={k} seed={seed}"
        np.testing.assert_allclose(np.asarray(s), sref, atol=5e-5,
                                   err_msg=msg)
        np.testing.assert_allclose(_fp64_profile(q, db, i), sref,
                                   rtol=0, atol=1e-12, err_msg=msg)
        ids = np.sort(np.asarray(i), axis=1)
        np.testing.assert_array_equal(ids[tie_free],
                                      np.sort(iref, axis=1)[tie_free],
                                      err_msg=msg)


def test_warm_start_and_best_first_improve_pruning(rng):
    """The lifted kernel-only optimizations now help the scan backend too."""
    db = clustered(rng, 4096, 32, n_centers=8, noise=0.04)
    q = db[rng.choice(4096, 64, replace=False)]
    q = jnp.asarray(q + 0.02 * rng.normal(size=q.shape).astype(np.float32))
    idx = build_index(jnp.asarray(db), n_pivots=16, block_size=64)
    base = SearchEngine(idx, backend="scan", warm_start=False,
                        best_first=False)
    eng = SearchEngine(idx, backend="scan")
    _, _, st0 = base.search(q, 5)
    _, _, st1 = eng.search(q, 5)
    assert st1.block_prune_frac > st0.block_prune_frac, (
        st0.block_prune_frac, st1.block_prune_frac)

    kern0 = SearchEngine(idx, backend="kernel", bm=16, warm_start=False,
                         best_first=False)
    kern1 = SearchEngine(idx, backend="kernel", bm=16)
    _, _, kt0 = kern0.search(q, 5)
    _, _, kt1 = kern1.search(q, 5)
    assert kt1.tile_computed_frac <= kt0.tile_computed_frac + 1e-6


def test_warm_start_engages_beyond_block_size(rng):
    """k > block_size: the multi-block prescan seeds τ instead of the old
    auto-disable, results stay exact, and pruning measurably improves."""
    db = clustered(rng, 2048, 24, n_centers=6, noise=0.05)
    q = db[::256] + 0.01 * rng.normal(size=(8, 24)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=32)
    k = 48                                     # > block_size = 32
    sref, iref = ref.brute_force_knn(np.asarray(q), db, k)
    cold = SearchEngine(idx, backend="scan", warm_start=False,
                        best_first=False)
    warm = SearchEngine(idx, backend="scan", warm_start=True,
                        best_first=False)
    _, _, st0 = cold.search(jnp.asarray(q), k)
    s, i, st1 = warm.search(jnp.asarray(q), k)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)
    assert _sets_equal(i, iref) > 0.98
    assert st1.block_prune_frac > st0.block_prune_frac, (
        st0.block_prune_frac, st1.block_prune_frac)


def test_warm_start_multiblock_seed_is_finite(rng):
    """The prescan covers ceil(k/bs) blocks, so every query gets a real
    τ seed even when k exceeds the block size."""
    from repro.kernels import ref as kref
    from repro.search.backends import (prep_queries, prescan_blocks,
                                       tau_warm_start)
    db = clustered(rng, 512, 16)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=32)
    qn, qp = prep_queries(idx, jnp.asarray(db[:5]))
    nb, bs = idx.n_blocks, idx.block_size
    ub = kref.block_bounds(qp, idx.dp_min, idx.dp_max)
    db_blocks = idx.db.reshape(nb, bs, -1)
    valid_blocks = idx.valid.reshape(nb, bs)
    k = 3 * bs + 1
    n_pre = prescan_blocks(k, bs, nb)
    assert n_pre == 4                          # ceil(k / bs)
    tau = tau_warm_start(qn, db_blocks, valid_blocks, ub, k, n_pre)
    assert np.isfinite(np.asarray(tau)).all()
    # and each seed is a true lower bound on the final kth-best similarity
    sref, _ = ref.brute_force_knn(db[:5], db, k)
    assert (np.asarray(tau) <= sref[:, -1] + 1e-6).all()


def test_warm_start_blocks_widens_prescan(rng):
    """warm_start_blocks only ever widens: tighter or equal seeds, exact
    results."""
    db = clustered(rng, 2048, 24, n_centers=6, noise=0.05)
    q = db[::256] + 0.01 * rng.normal(size=(8, 24)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=64)
    sref, _ = ref.brute_force_knn(np.asarray(q), db, 10)
    narrow = SearchEngine(idx, backend="scan", best_first=False)
    wide = SearchEngine(idx, backend="scan", best_first=False,
                        warm_start_blocks=4)
    _, _, st_n = narrow.search(jnp.asarray(q), 10)
    s, _, st_w = wide.search(jnp.asarray(q), 10)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)
    assert st_w.block_prune_frac >= st_n.block_prune_frac - 1e-6


def test_elem_prune_frac_scan_kernel_agree(rng):
    """Backend-uniform element stats: with matched granularity (bn = index
    block size, one query tile) the scan and kernel backends report the
    same elem_prune_frac on clustered data."""
    db = clustered(rng, 2048, 32, n_centers=6, noise=0.05)
    q = db[::64] + 0.01 * rng.normal(size=(32, 32)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=16, block_size=64)
    scan = SearchEngine(idx, backend="scan")
    kern = SearchEngine(idx, backend="kernel", bm=32, bn=64)
    _, _, st_s = scan.search(jnp.asarray(q), 10, element_stats=True)
    _, _, st_k = kern.search(jnp.asarray(q), 10, element_stats=True)
    es, ek = float(st_s.elem_prune_frac), float(st_k.elem_prune_frac)
    assert es > 0.3, es                        # clustered data must prune
    assert abs(es - ek) < 0.02, (es, ek)


def test_elem_prune_frac_reported_by_all_backends(rng):
    """element_stats=True yields a [0, 1] elem_prune_frac from every local
    backend (sharded covered in test_distributed.py), via the engine-level
    knob as well as the per-call override."""
    db = clustered(rng, 1024, 16)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=64)
    for backend in LOCAL_BACKENDS:
        eng = SearchEngine(idx, backend=backend, bm=8, element_stats=True)
        _, _, stats = eng.search(jnp.asarray(db[:4]), 5)
        assert stats.elem_prune_frac is not None, backend
        assert 0.0 <= float(stats.elem_prune_frac) <= 1.0, backend
        # per-call override wins over the engine default
        _, _, off = eng.search(jnp.asarray(db[:4]), 5, element_stats=False)
        assert off.elem_prune_frac is None, backend


def test_stats_dict_compat(rng):
    db = clustered(rng, 1000, 16)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=64)
    eng = SearchEngine(idx, backend="scan")
    _, _, stats = eng.search(jnp.asarray(db[:4]), 3, element_stats=True)
    assert stats["block_prune_frac"] == stats.block_prune_frac
    assert "elem_prune_frac" in stats.keys()
    d = stats.as_dict()
    assert d["backend"] == "scan" and 0.0 <= d["block_prune_frac"] <= 1.0
    with pytest.raises(KeyError):
        stats["nope"]


def test_stats_fraction_invariants(rng):
    """Every *_prune_frac / *_eval_frac / *_computed_frac is either None
    (the stage did not run) or a fraction in [0, 1]; a stage that did not
    run reports None, never a silent 0 — so dashboards can't mistake
    "not run" for "pruned nothing"."""
    db = clustered(rng, 1500, 16)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=32)
    frac_fields = ("block_prune_frac", "tile_computed_frac",
                   "elem_prune_frac", "tree_prune_frac",
                   "tree_node_eval_frac")
    for backend in LOCAL_BACKENDS + ["tree"]:
        eng = SearchEngine(idx, backend=backend, bm=8)
        _, _, stats = eng.search(jnp.asarray(db[:5]), 6, element_stats=True)
        for name in frac_fields:
            v = getattr(stats, name)
            assert v is None or 0.0 <= float(v) <= 1.0, (backend, name, v)
        if backend != "tree":
            # absent tree stage: None, not 0.0
            assert stats.tree_prune_frac is None, backend
            assert stats.tree_node_eval_frac is None, backend
        else:
            assert stats.tree_prune_frac is not None
            assert stats.tree_node_eval_frac is not None
        if backend != "kernel":
            assert stats.tile_computed_frac is None, backend
        # element stats off: None, not 0.0 (brute reports 0.0 when ON —
        # the stage ran and pruned nothing, by definition)
        _, _, off = eng.search(jnp.asarray(db[:5]), 6, element_stats=False)
        assert off.elem_prune_frac is None, backend
        # prune=False: the descent never runs, so the tree fracs must be
        # None even on the tree backend — not a silent 0.0
        _, _, noprune = eng.search(jnp.asarray(db[:5]), 6, prune=False)
        assert noprune.tree_prune_frac is None, backend
        assert noprune.tree_node_eval_frac is None, backend
        # never-mutated engine: the online fields are None, not 0 — an
        # engine that HAS an online handle reports real host numbers
        assert stats.generation is None and stats.decay_estimate is None
        eng.online(auto_reoptimize=False).insert(db[:1])
        _, _, onl = eng.search(jnp.asarray(db[:5]), 6)
        assert onl.generation == 1 and 0.0 < onl.decay_estimate <= 1.0


def test_engine_build_convenience(rng):
    db = clustered(rng, 500, 16)
    eng = SearchEngine.build(db, n_pivots=8, block_size=32)
    s, i, stats = eng.search(jnp.asarray(db[:6]), 4)
    sref, iref = ref.brute_force_knn(db[:6], db, 4)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)


def test_k_exceeds_valid_rows(rng):
    db = rng.normal(size=(40, 8)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=16)
    # (kernel excluded: it requires k <= bn, a documented tile constraint)
    for backend in ["scan", "brute"]:
        eng = SearchEngine(idx, backend=backend, bm=8)
        s, i, _ = eng.search(jnp.asarray(db[:2]), 40)
        sref, _ = ref.brute_force_knn(db[:2], db, 40)
        np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5,
                                   err_msg=backend)


def test_unknown_backend_raises(rng):
    db = rng.normal(size=(64, 8)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=32)
    with pytest.raises(ValueError, match="unknown search backend"):
        SearchEngine(idx, backend="mosaic-gpu")


def test_sharded_backend_requires_mesh(rng):
    db = rng.normal(size=(64, 8)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=32)
    # a flat 2D index can't serve the sharded backend at all — the engine
    # now rejects the pairing at construction (clear error instead of an
    # opaque reshape TypeError mid-trace; tests/test_backend_edges.py has
    # the mesh-supplied variant of this regression)
    with pytest.raises(ValueError, match="mesh"):
        SearchEngine(idx, backend="sharded")
