"""Docs stay runnable: every fenced block marked ``python doctest`` in
docs/*.md is executed as a self-contained script.

Only explicitly marked blocks run — plain ``python`` fences remain
illustrative fragments.  A marked block must import everything it uses
and finish in CI time (keep corpora tiny)."""
import os
import re

import pytest

DOCS = os.path.join(os.path.dirname(__file__), "..", "docs")
_FENCE = re.compile(r"```python doctest\n(.*?)```", re.DOTALL)


def _blocks():
    for fname in sorted(os.listdir(DOCS)):
        if not fname.endswith(".md"):
            continue
        with open(os.path.join(DOCS, fname)) as f:
            text = f.read()
        for i, block in enumerate(_FENCE.findall(text)):
            yield pytest.param(block, id=f"{fname}#{i}")


@pytest.mark.parametrize("block", _blocks())
def test_doc_block_runs(block):
    exec(compile(block, "<doc block>", "exec"), {"__name__": "__docs__"})


def test_docs_contain_marked_blocks():
    # the online + continuous-batching sections promise runnable examples;
    # losing the marker (e.g. an edit to the fence) must not silently turn
    # this suite into a no-op
    assert len(list(_blocks())) >= 2
