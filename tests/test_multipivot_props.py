"""Multi-pivot joint-bound validity (DESIGN.md §3.8).

ISSUE 7 satellite: the intersected k-pivot upper bound (the ``eq13_multi``
provider's cap) (a) never undercuts the true cosine — including the
adversarial near-antipodal, duplicate-pivot and in-span cases where the
radicand or the Cholesky factor degenerates, (b) dominates the
single-pivot Eq. 13 bound and tightens monotonically with depth (the
jittered-lift argument: more coordinates of the same orthonormal lift can
only shrink the residual term), and (c) leaves every backend tie-aware
brute-exact with the ``n_pivots`` knob switched on.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import bounds, ref
from repro.core.index import build_index, multipivot_block_cap
from repro.core.pivots import orthonormal_pivot_basis
from repro.search import SearchEngine
from tests.conftest import clustered


def _joint_ub(q, y, pivots, j):
    """The index's joint bound for explicit unit vectors, mirroring its
    precision split: fp64 basis + tables at build, fp32 evaluation."""
    u = orthonormal_pivot_basis(np.asarray(pivots, np.float64))   # [P, d]
    beta64 = np.asarray(y, np.float64)[None] @ u[:j].T            # [1, j]
    alpha = (jnp.asarray(q, jnp.float32)[None]
             @ jnp.asarray(u[:j], jnp.float32).T)
    beta = jnp.asarray(beta64, jnp.float32)
    bnsq = jnp.asarray((beta64 * beta64).sum(axis=1), jnp.float32)
    return float(bounds.joint_row_upper_bound(alpha, beta, bnsq)[0, 0])


def _unit(rng, d):
    return ref.normalize(rng.normal(size=(1, d)))[0]


# ---------------------------------------------------------------------------
# (a) validity: the joint bound never undercuts the true fp64 cosine
# ---------------------------------------------------------------------------

@settings(max_examples=150, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 32), st.integers(1, 6),
       st.sampled_from(["random", "antipodal", "duplicate_pivots",
                        "in_span", "query_is_pivot"]))
def test_joint_ub_never_undercuts_true_cosine(seed, d, j, kind):
    rng = np.random.default_rng(seed)
    q = _unit(rng, d)
    piv = ref.normalize(rng.normal(size=(max(2, j), d)))
    if kind == "antipodal":
        # near-antipodal target: s ~ -1, the radicand-clamp corner
        y = ref.normalize((-q + 1e-6 * rng.normal(size=d))[None])[0]
    elif kind == "duplicate_pivots":
        # all-identical pivot set: singular Gram, the jitter-escalation
        # path of orthonormal_pivot_basis
        piv = np.repeat(piv[:1], len(piv), axis=0)
        y = _unit(rng, d)
    elif kind == "in_span":
        # y inside the pivot span: ||beta|| ~ 1, residual ~ 0 — the bound
        # collapses to the fp32 dot product, where only the slack protects
        y = piv.T @ rng.normal(size=len(piv))
        nrm = np.linalg.norm(y)
        y = piv[0] if nrm < 1e-9 else y / nrm
    elif kind == "query_is_pivot":
        q = piv[0]
        y = _unit(rng, d)
    else:
        y = _unit(rng, d)
    true = float(np.asarray(q, np.float64) @ np.asarray(y, np.float64))
    assert _joint_ub(q, y, piv, j) >= true - 1e-6, (kind, seed, d, j)


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 24), st.integers(1, 6))
def test_block_cap_never_undercuts_block_max(seed, d, j):
    """Block granularity: the cap for every (query, block) pair sits at or
    above the largest true similarity inside that block."""
    rng = np.random.default_rng(seed)
    db = rng.normal(size=(96, d)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=16)
    q = ref.normalize(rng.normal(size=(3, d))).astype(np.float32)
    cap = np.asarray(multipivot_block_cap(idx, jnp.asarray(q), n_pivots=j))
    true = ref.cosine_matrix(q, db)                       # fp64 [3, 96]
    rows = np.asarray(idx.row_ids)
    for b in range(idx.n_blocks):
        ids = rows[b * 16:(b + 1) * 16]
        ids = ids[ids >= 0]
        if len(ids) == 0:
            continue
        assert (cap[:, b] >= true[:, ids].max(axis=1) - 1e-6).all(), (b, j)


# ---------------------------------------------------------------------------
# (b) dominance: joint(1) <= Eq. 13 on the first pivot; monotone in depth
# ---------------------------------------------------------------------------

@settings(max_examples=150, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 32), st.integers(2, 6))
def test_joint_ub_dominates_single_pivot_eq13(seed, d, p):
    rng = np.random.default_rng(seed)
    q, y = _unit(rng, d), _unit(rng, d)
    piv = ref.normalize(rng.normal(size=(p, d)))
    ubs = [_joint_ub(q, y, piv, j) for j in range(1, p + 1)]
    single = float(ref.ub_mult(float(np.float64(q @ piv[0])),
                               float(np.float64(y @ piv[0]))))
    # the eps-jitter lift moves the j=1 bound by O(sqrt(eps)) only where
    # 1 - s^2 ~ eps (the pole); 2e-3 is the same pole allowance
    # test_pivot_set_bounds uses, plus the bound's own additive slack
    assert ubs[0] <= single + 2e-3 + bounds.JOINT_SLACK
    # deeper prefixes only tighten (identical slack on both sides cancels;
    # the margin is pure fp32 evaluation noise)
    for deeper, shallower in zip(ubs[1:], ubs):
        assert deeper <= shallower + 5e-5, (seed, d, p)


# ---------------------------------------------------------------------------
# (c) engine equivalence: every backend stays brute-exact with the knob on
# ---------------------------------------------------------------------------

def _fp64_profile(q, db, ids):
    """Exact fp64 similarity profile of a returned id set, sorted desc —
    tie-safe where raw id comparison is not."""
    qn = q.astype(np.float64)
    qn /= np.linalg.norm(qn, axis=1, keepdims=True)
    dbn = db.astype(np.float64)
    dbn /= np.linalg.norm(dbn, axis=1, keepdims=True)
    sims = np.einsum("md,mkd->mk", qn, dbn[np.maximum(np.asarray(ids), 0)])
    sims = np.where(np.asarray(ids) >= 0, sims, -np.inf)
    return -np.sort(-sims, axis=1)


def _adversarial(rng, n, d):
    n_dup = n // 3
    base = clustered(rng, n - n_dup, d, n_centers=4, noise=0.01)
    dup = base[rng.integers(0, len(base), n_dup)] + 1e-4 * rng.normal(
        size=(n_dup, d)).astype(np.float32)
    x = np.concatenate([base, dup])
    return (x / np.linalg.norm(x, axis=1, keepdims=True)).astype(np.float32)


@settings(max_examples=4, deadline=None)
@given(st.integers(60, 400), st.integers(4, 24), st.integers(1, 10),
       st.integers(0, 10_000))
def test_all_backends_match_brute_with_joint_cap(n, d, k, seed):
    """scan / kernel / tree / sharded / sharded_tree with the joint cap
    intersected all return the fp64 brute result set (profile-equal on
    ties), and report the resolved depth in stats."""
    import jax
    from repro.core.distributed import (build_sharded_index,
                                        place_sharded_index)
    rng = np.random.default_rng(seed)
    kind = seed % 3
    if kind == 0:
        db = rng.normal(size=(n, d)).astype(np.float32)
    elif kind == 1:
        db = clustered(rng, n, d)
    else:
        db = _adversarial(rng, n, d)
    k = min(k, n)
    q = rng.normal(size=(3, d)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=32)
    sref, _ = ref.brute_force_knn(q, db, k)               # fp64 oracle

    mesh = jax.make_mesh((1,), ("data",))
    sidx = place_sharded_index(
        build_sharded_index(db, 1, n_pivots=4, block_size=32), mesh)
    for npv in (1, 2, 4):
        runs = {
            "scan": SearchEngine(idx, backend="scan", n_pivots=npv),
            "kernel": SearchEngine(idx, backend="kernel", bm=8,
                                   n_pivots=npv),
            "tree": SearchEngine(idx, backend="tree", bm=8, n_pivots=npv),
            "sharded": SearchEngine(sidx, mesh=mesh, tree_shards=False,
                                    n_pivots=npv),
            "sharded_tree": SearchEngine(sidx, mesh=mesh, tree_shards=True,
                                         n_pivots=npv),
        }
        for name, eng in runs.items():
            s, i, stats = eng.search(jnp.asarray(q), k)
            msg = f"{name} npv={npv} n={n} d={d} k={k} seed={seed}"
            np.testing.assert_allclose(np.asarray(s), sref, atol=5e-5,
                                       err_msg=msg)
            np.testing.assert_allclose(_fp64_profile(q, db, i), sref,
                                       rtol=0, atol=1e-12, err_msg=msg)
            assert stats.n_pivots == npv, msg


def test_explicit_depth_beyond_table_width_clamps(rng):
    """Asking for more depth than the index holds bound tables for clamps
    to the table width (and stays exact) rather than erroring."""
    db = clustered(rng, 300, 16)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=32)
    q = db[:5] + np.float32(0.01) * rng.normal(size=(5, 16)).astype(
        np.float32)
    eng = SearchEngine(idx, backend="scan", n_pivots=99)
    assert eng.n_pivots == idx.bound_table_width == 4
    s, _, stats = eng.search(jnp.asarray(q), 7)
    sref, _ = ref.brute_force_knn(q, db, 7)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)
    assert stats.n_pivots == 4
