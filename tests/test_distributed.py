"""Multi-device tests run in subprocesses with virtual CPU devices (the main
test process must keep exactly one device)."""
import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    # pin the subprocess to the host platform: with a TPU plugin installed
    # but no TPU attached, backend autodetection stalls for minutes in
    # GCP-metadata retries before falling back
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_search_exact():
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ref
        from repro.core.distributed import (build_sharded_index,
            make_sharded_search, place_sharded_index)
        rng = np.random.default_rng(1)
        db = rng.normal(size=(4097, 24)).astype(np.float32)
        q = rng.normal(size=(9, 24)).astype(np.float32)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        idx = place_sharded_index(build_sharded_index(db, 8, n_pivots=8,
                                                      block_size=64), mesh)
        run = make_sharded_search(mesh)
        s, i = run(idx, jnp.asarray(q), 7)
        sref, iref = ref.brute_force_knn(q, db, 7)
        np.testing.assert_allclose(np.asarray(s), sref, atol=2e-5)
        assert (np.asarray(i) == iref).mean() > 0.98
        print("ok")
    """)


def test_search_engine_sharded_backend():
    """SearchEngine auto-selects the sharded backend on a mesh and matches
    brute force, with warm-start/best-first applied per shard."""
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ref
        from repro.search import SearchEngine
        rng = np.random.default_rng(7)
        c = ref.normalize(rng.normal(size=(6, 24)))
        db = ref.normalize(c[rng.integers(0, 6, 4000)] +
                           0.05 * rng.normal(size=(4000, 24))).astype(np.float32)
        q = ref.normalize(db[::500] + 0.01 * rng.normal(size=(8, 24))
                          ).astype(np.float32)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        eng = SearchEngine.build(db, n_pivots=8, block_size=64, mesh=mesh)
        assert eng.backend_name == "sharded"
        s, i, stats = eng.search(jnp.asarray(q), 7, element_stats=True)
        sref, iref = ref.brute_force_knn(q, db, 7)
        np.testing.assert_allclose(np.asarray(s), sref, atol=2e-5)
        assert (np.asarray(i) == iref).mean() > 0.98
        assert 0.0 <= stats.block_prune_frac <= 1.0
        # element stats are backend-uniform: the sharded path reports the
        # global (psum-weighted) element-prune fraction too
        assert 0.0 < float(stats.elem_prune_frac) <= 1.0
        # k > per-shard block size: the multi-block tau prescan engages on
        # every shard and the merge stays exact
        s2, i2, st2 = eng.search(jnp.asarray(q), 80)
        sref2, _ = ref.brute_force_knn(q, db, 80)
        np.testing.assert_allclose(np.asarray(s2), sref2, atol=2e-5)
        print("ok, shard prune_frac", stats.block_prune_frac,
              "elem", float(stats.elem_prune_frac))
    """)


def test_train_step_on_mesh_moe():
    """pjit train step with sharding rules + shard_map MoE on a 2x2 mesh."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.dist import sharding as shd
        from repro.models import model_fns, synthetic_batch
        from repro.train.train_step import make_train_step, init_state
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        shd.set_rules(mesh, shd.default_rules(fsdp=True))
        cfg = smoke_config("granite-moe-1b-a400m").replace(
            d_model=64, d_ff=64, vocab=128)
        fns = model_fns(cfg)
        step = jax.jit(make_train_step(fns, cfg))
        state = init_state(fns, jax.random.PRNGKey(0))
        batch = synthetic_batch(cfg, 4, 32)
        batch = jax.device_put(batch, NamedSharding(mesh, P("data")))
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        state, m2 = step(state, batch)
        assert float(m2["loss"]) < float(metrics["loss"]) + 1.0
        print("loss", float(m2["loss"]))
    """, devices=4)


def test_sharded_vs_local_moe_equivalence():
    """shard_map MoE == local MoE on the same inputs (modulo drop order)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.dist import sharding as shd
        from repro.models.moe import moe_init, moe_apply
        from repro.models.config import MoEConfig
        cfg = smoke_config("mixtral-8x22b").replace(
            dtype="float32",
            moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0))
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        y_local, _ = moe_apply(p, x, cfg, no_drop=True)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        shd.set_rules(mesh, shd.default_rules(fsdp=False))
        y_shard, _ = jax.jit(lambda p_, x_: moe_apply(p_, x_, cfg,
                                                      no_drop=True))(p, x)
        shd.set_rules(None, None)
        np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_shard),
                                   atol=2e-4)
        print("ok")
    """, devices=4)


def test_elastic_restore_smaller_mesh(tmp_path):
    """Checkpoint on a 2x4 mesh restores onto a 2x3 mesh (node loss)."""
    _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        from repro.dist.elastic import remesh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        t = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        t = jax.device_put(t, NamedSharding(mesh, P(None, "model")))
        cm = CheckpointManager(r"{tmp_path}", async_save=False)
        cm.save(1, t)
        # 2 devices "fail": rebuild mesh from 6 survivors
        new_mesh = remesh(jax.devices()[:6], prefer_model=2)
        sh = {{"w": NamedSharding(new_mesh, P(None, "model"))}}
        got, _, _ = cm.restore(jax.tree.map(jnp.zeros_like, t), shardings=sh)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(64).reshape(8, 8))
        print("remeshed to", new_mesh.shape)
    """, devices=8)


def test_dryrun_single_cell_small():
    """End-to-end dryrun on the production 16x16 mesh (one small cell)."""
    _run("""
        from repro.launch.dryrun import run_cell
        rec = run_cell("granite-3-2b", "decode_32k", "pod",
                       out_dir="/tmp/dryrun_test")
        assert "memory" in rec, rec.get("error")
        assert rec["collectives"], "expected collectives in a TP decode"
        print("bytes/dev",
              rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"])
    """, devices=512)
