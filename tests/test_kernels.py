"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ref as cref
from repro.core.index import build_index
from repro.kernels import ref as kref
from repro.kernels.bound_prune import block_bounds as bp_kernel
from repro.kernels.cosine_topk import pruned_topk
from repro.search.backends import kernel_search, map_row_ids, prep_queries
from tests.conftest import clustered


def _raw_kernel(idx, q, k, **kw):
    """Fixed-policy kernel inner loop (the historical ``ops.search_index``
    surface: no τ warm-start, natural block order) -> (sims, ids,
    mean computed-tile fraction)."""
    qn, qp = prep_queries(idx, jnp.asarray(q))
    sims, pos, computed, _ = kernel_search(idx, qn, qp, k, **kw)
    return sims, map_row_ids(idx.row_ids, pos), computed.mean()


@pytest.mark.parametrize("m,nb,p", [(8, 4, 4), (37, 19, 12), (128, 64, 16),
                                    (256, 8, 8), (5, 100, 3)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_bound_prune_sweep(m, nb, p, dtype, rng):
    qp = np.clip(rng.normal(0, 0.5, size=(m, p)), -1, 1).astype(dtype)
    lo = np.clip(rng.uniform(-1, 0.5, size=(nb, p)), -1, 1).astype(dtype)
    hi = np.clip(lo + rng.uniform(0, 0.5, size=(nb, p)), -1, 1).astype(dtype)
    got = bp_kernel(jnp.asarray(qp), jnp.asarray(lo), jnp.asarray(hi),
                    bm=32, bb=32, interpret=True)
    want = kref.block_bounds(jnp.asarray(qp), jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5 if dtype == np.float32 else 1e-6)


@pytest.mark.parametrize("n,d,k,bm,bn", [
    (512, 16, 4, 16, 128), (1024, 32, 9, 32, 256), (768, 48, 16, 8, 128),
])
def test_cosine_topk_sweep(n, d, k, bm, bn, rng):
    db = clustered(rng, n, d)
    q = clustered(rng, 40, d)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=128)
    s_k, i_k, frac = _raw_kernel(idx, q, k, bm=bm, bn=bn)
    sref, iref = cref.brute_force_knn(q, db, k)
    np.testing.assert_allclose(np.asarray(s_k), sref, atol=3e-5)
    got = np.sort(np.asarray(i_k), 1)
    want = np.sort(iref, 1)
    assert (got == want).mean() > 0.98


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cosine_topk_dtypes(dtype, rng):
    db = clustered(rng, 512, 32)
    q = clustered(rng, 16, 32)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=128)
    idx = idx._replace(db=idx.db.astype(dtype))
    s_k, i_k, _ = _raw_kernel(idx, q, 5, bm=16)
    sref, _ = cref.brute_force_knn(q, db, 5)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(s_k), sref, atol=tol)


def test_pruning_engages_and_stays_exact(rng):
    db = clustered(rng, 4096, 32, n_centers=8, noise=0.04)
    # near-datastore queries (the kNN-LM/dedup regime): tau rises fast
    q = db[rng.choice(4096, 128, replace=False)]
    q = (q + 0.02 * rng.normal(size=q.shape).astype(np.float32))
    idx = build_index(jnp.asarray(db), n_pivots=16, block_size=128)
    s_p, i_p, frac_p = _raw_kernel(idx, q, 5, bm=16)
    s_n, i_n, frac_n = _raw_kernel(idx, q, 5, bm=16, prune=False)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_n), atol=1e-6)
    assert float(frac_n) == 1.0
    assert float(frac_p) < 0.9, f"expected pruning, computed {float(frac_p)}"


def test_query_sort_improves_pruning(rng):
    db = clustered(rng, 4096, 32, n_centers=8, noise=0.04)
    q = clustered(rng, 256, 32, n_centers=8, noise=0.04)
    idx = build_index(jnp.asarray(db), n_pivots=16, block_size=128)
    _, _, f_sorted = _raw_kernel(idx, q, 5, bm=16, sort_queries=True)
    _, _, f_unsorted = _raw_kernel(idx, q, 5, bm=16, sort_queries=False)
    assert float(f_sorted) <= float(f_unsorted) + 1e-6


def test_ops_search_index_removed(rng):
    """The deprecated wrapper is a hard error now, with the migration
    hint — it must not silently fall through to a legacy policy."""
    from repro.kernels import ops
    db = clustered(rng, 256, 16)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=128)
    with pytest.raises(TypeError, match="SearchEngine"):
        ops.search_index(idx, jnp.asarray(db[:2]), 3)


def test_raw_kernel_interface(rng):
    """Direct pruned_topk call with hand-built intervals."""
    db = cref.normalize(rng.normal(size=(256, 16))).astype(np.float32)
    q = cref.normalize(rng.normal(size=(8, 16))).astype(np.float32)
    piv = db[:4]
    qp = (q @ piv.T).astype(np.float32)
    dp = (db @ piv.T).astype(np.float32)
    bn = 64
    lo = dp.reshape(-1, bn, 4).min(1)
    hi = dp.reshape(-1, bn, 4).max(1)
    s, i, computed, elem = pruned_topk(
        jnp.asarray(q), jnp.asarray(db), jnp.asarray(qp), jnp.asarray(lo),
        jnp.asarray(hi), 256, k=4, bm=8, bn=bn, interpret=True)
    assert elem is None                     # element_stats off by default
    sref, iref = cref.brute_force_knn(q, db, 4)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)
    assert (np.asarray(i) == iref).mean() > 0.98


def test_raw_kernel_element_counter(rng):
    """element_stats=True: per-tile pruned-element counts are sane and the
    result set is unchanged."""
    db = clustered(rng, 512, 16, n_centers=4, noise=0.05)
    q = db[:8] + 0.01 * rng.normal(size=(8, 16)).astype(np.float32)
    q = cref.normalize(q).astype(np.float32)
    piv = db[:: 512 // 8][:8]
    qp = (q @ piv.T).astype(np.float32)
    dp = (db @ piv.T).astype(np.float32)
    bn = 64
    lo = dp.reshape(-1, bn, 8).min(1)
    hi = dp.reshape(-1, bn, 8).max(1)
    s, i, computed, elem = pruned_topk(
        jnp.asarray(q), jnp.asarray(db), jnp.asarray(qp), jnp.asarray(lo),
        jnp.asarray(hi), 512, dp=jnp.asarray(dp), k=4, bm=8, bn=bn,
        interpret=True, element_stats=True)
    sref, _ = cref.brute_force_knn(q, db, 4)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)
    elem = np.asarray(elem)
    assert elem.shape == computed.shape
    assert (elem >= 0).all() and (elem <= 8 * bn).all()
    # clustered near-duplicate queries: τ rises fast, some elements prune
    assert elem.sum() > 0
