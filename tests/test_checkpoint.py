"""Checkpoint manager: roundtrip, integrity, GC, crash-safety, remesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.dist.elastic import best_mesh


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": [jnp.ones((3,)), jnp.zeros((2, 2))]},
    }


def test_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree()
    cm.save(3, t, extra={"data": {"pos": 7}})
    got, extra, step = cm.restore(jax.tree.map(jnp.zeros_like, t))
    assert step == 3 and extra["data"]["pos"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=True)
    cm.save(1, _tree())
    cm.wait()
    assert cm.latest_step() == 1


def test_integrity_detection(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree()
    cm.save(1, t)
    # corrupt the shard
    p = os.path.join(str(tmp_path), "step_00000001", "shard_p0.npz")
    data = dict(np.load(p))
    data["a"] = data["a"] + 1.0
    np.savez(p, **data)
    with pytest.raises(IOError):
        cm.restore(jax.tree.map(jnp.zeros_like, t))


def test_gc_keeps_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree())
    assert cm.steps() == [3, 4]


def test_incomplete_checkpoint_ignored(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    cm.save(1, _tree())
    # fake a crashed save: dir without DONE
    os.makedirs(os.path.join(str(tmp_path), "step_00000002"))
    assert cm.latest_step() == 1


def test_restore_with_resharding(tmp_path):
    """Elastic path: restore onto explicit shardings of a (1,1) mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cm = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree()
    cm.save(1, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    got, _, _ = cm.restore(jax.tree.map(jnp.zeros_like, t), shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_best_mesh_after_loss():
    assert best_mesh(256) == (16, 16) or best_mesh(256)[0] * best_mesh(256)[1] == 256
    d, m = best_mesh(240, prefer_model=16)
    assert d * m == 240
    d, m = best_mesh(7)
    assert d * m == 7
