"""q-group head padding (§Perf.S2): padded attention must be bit-exact.

Zero query heads inserted at each KV group's tail attend (harmlessly) and
their outputs are sliced off before wo — the padded model is the same
function with a TP-shardable head count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import model_fns, synthetic_batch


@pytest.mark.parametrize("arch,g_pad,kv_rep", [
    ("tinyllama-1.1b", 6, 2),     # GQA: g 4 -> 6 with kv replication
    ("internvl2-1b", 7, 1),       # g 4 -> 7, no replication
    ("whisper-small", 3, 1),      # MHA enc-dec: g 1 -> 3
])
def test_head_pad_exact_forward(arch, g_pad, kv_rep):
    base = smoke_config(arch).replace(dtype="float32")
    fns0 = model_fns(base)
    params = fns0.init(jax.random.PRNGKey(0))
    batch = synthetic_batch(base, 2, 12, seed=1)
    h0, _, _ = fns0.forward(params, batch)
    padded = base.replace(q_group_pad=g_pad, kv_repeat=kv_rep)
    fns1 = model_fns(padded)
    h1, _, _ = fns1.forward(params, batch)
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))


def test_head_pad_decode_consistent():
    base = smoke_config("tinyllama-1.1b").replace(dtype="float32")
    fns0 = model_fns(base)
    params = fns0.init(jax.random.PRNGKey(0))
    batch = synthetic_batch(base, 2, 10, seed=2)
    h0, _, _ = fns0.forward(params, batch)
    padded = base.replace(q_group_pad=6, kv_repeat=2)
    fns1 = model_fns(padded)
    cache = fns1.cache_init(params, batch, 2, 32)
    hs = []
    for t in range(10):
        hh, cache = fns1.decode_step(params, batch["tokens"][:, t:t + 1],
                                     cache, jnp.int32(t))
        hs.append(hh)
    err = float(jnp.abs(h0 - jnp.concatenate(hs, 1)).max())
    assert err < 5e-3, err
