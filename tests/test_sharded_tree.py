"""Tree × sharded composition: the per-shard transitive Eq. 13 descent under
shard_map (DESIGN.md §3.6), promoted from tools/sharded_smoke.py into the
tier-1 suite.  Runs in subprocesses with 8 virtual CPU devices (the main
test process must keep exactly one device, see conftest.py)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    # pin the subprocess to the host platform: with a TPU plugin installed
    # but no TPU attached, backend autodetection stalls for minutes in
    # GCP-metadata retries before falling back
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout

# the shared corpus: clustered (the regime with pruning power), sized so
# 8 shards are *unevenly* filled (4099 rows -> the last shard is short),
# with block_size 32 so k=48 exercises k > block size end to end
_SETUP = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import ref
    from repro.search import SearchEngine
    rng = np.random.default_rng(11)
    c = ref.normalize(rng.normal(size=(6, 24)))
    db = ref.normalize(c[rng.integers(0, 6, 4099)]
                       + 0.05 * rng.normal(size=(4099, 24))).astype(np.float32)
    q = ref.normalize(db[::400] + 0.01 * rng.normal(size=(11, 24))
                      ).astype(np.float32)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
"""


def test_sharded_tree_matches_brute_k_sweep():
    """sharded + per-shard tree descent returns the brute-force result set
    for k in {1, 8, 48} (48 > block_size=32: the multi-block prescan, the
    mask-carrying tau merge, and the all-gather merge all engage)."""
    _run(_SETUP + """
    eng = SearchEngine.build(db, n_pivots=8, block_size=32, mesh=mesh,
                             tree_shards=True)
    assert eng.backend_name == "sharded", eng.backend_name
    for k in (1, 8, 48):
        s, i, stats = eng.search(jnp.asarray(q), k, element_stats=True)
        sref, iref = ref.brute_force_knn(q, db, k)
        np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5,
                                   err_msg=f"k={k}")
        assert (np.sort(np.asarray(i), 1) == np.sort(iref, 1)).all(), k
        # the tree stage ran and reported itself
        assert 0.0 <= float(stats.tree_prune_frac) <= 1.0, k
        assert 0.0 < float(stats.tree_node_eval_frac) <= 1.0, k
        assert 0.0 <= float(stats.block_prune_frac) <= 1.0, k
        assert 0.0 <= float(stats.elem_prune_frac) <= 1.0, k
    print("ok")
    """)


def test_sharded_tree_prunes_at_least_flat():
    """The broadcast global tau makes every shard's pruning a superset of
    the flat per-shard pruning: block_prune_frac(tree) >= flat, and on
    clustered data the descent alone beats the flat fraction (the
    acceptance bar BENCH_pruning.json gates)."""
    _run(_SETUP + """
    flat = SearchEngine.build(db, n_pivots=8, block_size=32, mesh=mesh,
                              tree_shards=False)
    tree = SearchEngine.build(db, n_pivots=8, block_size=32, mesh=mesh,
                              tree_shards=True)
    for k in (8, 48):
        sf, _, stf = flat.search(jnp.asarray(q), k)
        st, _, stt = tree.search(jnp.asarray(q), k)
        np.testing.assert_allclose(np.asarray(st), np.asarray(sf), atol=3e-5)
        assert stf.tree_prune_frac is None and stf.tree_node_eval_frac is None
        blk_f, blk_t = float(stf.block_prune_frac), float(stt.block_prune_frac)
        assert blk_t >= blk_f - 1e-6, (k, blk_f, blk_t)
        assert float(stt.tree_prune_frac) >= blk_f - 1e-6, (
            k, blk_f, float(stt.tree_prune_frac))
    print("ok")
    """)


def test_sharded_flat_stats_are_psum_weighted():
    """The sharded aggregates equal the psum-weighted mean of per-shard
    stats: sums of per-shard counts over sums of per-shard denominators
    (uneven last shard included) — the weighting bug class PR 2 fixed by
    hand for elem_prune_frac, now pinned for every fraction."""
    _run(_SETUP + """
    from repro.search.backends import prep_queries, scan_search
    eng = SearchEngine.build(db, n_pivots=8, block_size=32, mesh=mesh,
                             tree_shards=False)
    _, _, stats = eng.search(jnp.asarray(q), 8, element_stats=True)
    idx = eng.index
    S = idx.db.shape[0]
    blk = elem = nbs = nvalid = 0.0
    for s in range(S):
        local = jax.tree.map(lambda x: x[s], idx)
        qn, qp = prep_queries(local, jnp.asarray(q))
        _, _, bp, ep = scan_search(local, qn, qp, 8, warm_start=True,
                                   best_first=True, element_stats=True)
        blk += float(bp); elem += float(ep)
        nbs += local.n_blocks
        nvalid += float(np.asarray(local.valid).sum())
    m = len(q)
    np.testing.assert_allclose(float(stats.block_prune_frac),
                               blk / (m * nbs), rtol=1e-6)
    np.testing.assert_allclose(float(stats.elem_prune_frac),
                               elem / (m * nvalid), rtol=1e-6)
    print("ok")
    """)


def test_sharded_tree_stats_are_psum_weighted():
    """Host re-implementation of the whole sharded tree stage (per-shard
    beam warm start -> global masked tau merge -> per-shard descent ->
    flat reseed -> masked leaf scan) reproduces every reported aggregate,
    proving the shard_map composition computes exactly this."""
    _run(_SETUP + """
    from repro.search import build_shard_trees
    from repro.search.backends import (prep_queries, prescan_blocks,
                                      scan_search, tau_warm_start)
    from repro.search.tree import TreeIndex, tree_descend, tree_warm_start_topk
    k = 8
    eng = SearchEngine.build(db, n_pivots=8, block_size=32, mesh=mesh,
                             tree_shards=True)
    _, _, stats = eng.search(jnp.asarray(q), k, element_stats=True)
    idx, tr = eng.index, build_shard_trees(eng.index)
    S, m = idx.db.shape[0], len(q)
    locals_, prepped, cands = [], [], []
    for s in range(S):
        local = jax.tree.map(lambda x: x[s], idx)
        ltree = TreeIndex(local, tr.node_lo[s], tr.node_hi[s],
                          tr.node_valid[s])
        qn, qp = prep_queries(local, jnp.asarray(q))
        n_pre = prescan_blocks(k, local.block_size, local.n_blocks, None)
        cands.append(tree_warm_start_topk(ltree, qn, qp, k, n_pre))
        locals_.append((local, ltree, n_pre)); prepped.append((qn, qp))
    # host-side mask-carrying merge: k-th best real candidate of the union
    cs = np.concatenate([np.asarray(c[0]) for c in cands], axis=1)
    cv = np.concatenate([np.asarray(c[1]) for c in cands], axis=1)
    cs = np.where(cv, cs, -np.inf)
    order = np.argsort(-cs, axis=1)
    kth_s = np.take_along_axis(cs, order, 1)[:, k - 1]
    kth_v = np.take_along_axis(cv, order, 1)[:, k - 1]
    tau_g = jnp.asarray(np.where(kth_v, kth_s, -np.inf), jnp.float32)
    blk = elem = tpruned = evals = nbs = nvalid = nnodes = 0.0
    for s in range(S):
        local, ltree, n_pre = locals_[s]
        qn, qp = prepped[s]
        nb, bs = local.n_blocks, local.block_size
        alive, leaf_ub, ev = tree_descend(ltree, qp, tau_g)
        tau0 = jnp.maximum(tau_g, tau_warm_start(
            qn, local.db.reshape(nb, bs, -1), local.valid.reshape(nb, bs),
            leaf_ub, k, n_pre))
        _, _, bp, ep = scan_search(local, qn, qp, k, warm_start=False,
                                   best_first=True, element_stats=True,
                                   tau0=tau0, ub_all=leaf_ub, leaf_mask=alive)
        blk += float(bp); elem += float(ep)
        tpruned += float((~np.asarray(alive)).sum()); evals += float(ev)
        nbs += nb
        nvalid += float(np.asarray(local.valid).sum())
        nnodes += float(np.asarray(ltree.node_valid).sum())
    np.testing.assert_allclose(float(stats.block_prune_frac),
                               blk / (m * nbs), rtol=1e-6)
    np.testing.assert_allclose(float(stats.elem_prune_frac),
                               elem / (m * nvalid), rtol=1e-6)
    np.testing.assert_allclose(float(stats.tree_prune_frac),
                               tpruned / (m * nbs), rtol=1e-6)
    np.testing.assert_allclose(float(stats.tree_node_eval_frac),
                               evals / (m * nnodes), rtol=1e-6)
    print("ok")
    """)
