"""End-to-end behaviour tests for the paper's system: the full pipeline
(embed -> dedup -> train -> datastore -> kNN-LM serve) on a tiny model."""
import jax
import numpy as np

from repro.configs import smoke_config
from repro.data.dedup import dedup_mask, embed_tokens, find_near_duplicates
from repro.data.pipeline import SyntheticLM
from repro.models import model_fns, synthetic_batch
from repro.serve.engine import Engine
from repro.serve.knnlm import KNNDatastore
from repro.train.train_step import init_state, make_train_step


def test_full_stack_end_to_end(tmp_path):
    cfg = smoke_config("tinyllama-1.1b").replace(
        n_layers=2, d_model=32, d_ff=64, n_heads=2, n_kv_heads=2, d_head=16,
        vocab=64, dtype="float32")
    fns = model_fns(cfg)

    # 1) data with near-duplicates -> dedup via the paper's exact search
    src = SyntheticLM(cfg.vocab, 16, 16, seed=0)
    toks = src.batch(0)["tokens"]
    toks[9] = toks[2]
    emb = embed_tokens(toks)
    pairs, _ = find_near_duplicates(emb, threshold=0.95, k=4, n_pivots=4,
                                    block_size=32)
    keep = dedup_mask(len(toks), pairs)
    assert not keep[9] and keep[2]

    # 2) short training run
    step = jax.jit(make_train_step(fns, cfg))
    state = init_state(fns, jax.random.PRNGKey(0))
    for s in range(8):
        state, metrics = step(state, src.batch(s))
    assert np.isfinite(float(metrics["loss"]))

    # 3) harvest a datastore from the trained model and serve with kNN-LM
    params = state["params"]
    batches = [synthetic_batch(cfg, 2, 16, seed=s) for s in range(2)]
    ds = KNNDatastore.from_corpus(fns, params, batches, cfg.vocab, k=4,
                                  n_pivots=4, block_size=32)
    eng = Engine(fns, params, max_seq=32, knn=ds, lmbda=0.25)
    prompt = synthetic_batch(cfg, 2, 8, seed=5)
    cache, clen, _ = eng.prefill(prompt)
    out, _ = eng.decode(cache, clen, prompt["tokens"][:, -1:], 4)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.vocab
