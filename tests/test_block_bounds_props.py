"""Property tests for interval_upper_bound / block_upper_bound (Eq. 13 over
pivot intervals): the block bound must dominate every member's bound, for
both the pure-JAX and the Pallas (interpret) implementations."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ref
from repro.core.index import block_upper_bound, interval_upper_bound
from repro.kernels import ref as kref
from repro.kernels.bound_prune import block_bounds as bp_kernel


def _random_intervals(rng, nb, p, *, contain_qp=None, qp=None, degenerate=False):
    lo = rng.uniform(-1, 1, size=(nb, p))
    if degenerate:
        hi = lo.copy()
    else:
        hi = np.minimum(1.0, lo + rng.uniform(0, 0.8, size=(nb, p)))
    if contain_qp is True and qp is not None:
        # widen so every interval contains every query's pivot similarity
        lo = np.minimum(lo, qp.min(axis=0)[None, :] - 1e-6)
        hi = np.maximum(hi, qp.max(axis=0)[None, :] + 1e-6)
    elif contain_qp is False and qp is not None:
        # shift intervals strictly above every qp
        top = qp.max()
        lo = np.clip(top + 0.05 + 0.3 * rng.uniform(size=(nb, p)), -1, 0.999)
        hi = np.clip(lo + 0.001, -1, 1)
    return lo.astype(np.float32), hi.astype(np.float32)


def test_interval_bound_inside_is_one(rng):
    qp = np.clip(rng.normal(0, 0.4, size=(9, 5)), -0.99, 0.99).astype(np.float32)
    lo, hi = _random_intervals(rng, 7, 5, contain_qp=True, qp=qp)
    for b in range(7):
        ub = interval_upper_bound(jnp.asarray(qp), jnp.asarray(lo[b]),
                                  jnp.asarray(hi[b]))
        np.testing.assert_allclose(np.asarray(ub), 1.0)


def test_interval_bound_excluding_qp_below_one(rng):
    qp = np.clip(rng.normal(0, 0.2, size=(6, 4)), -0.6, 0.6).astype(np.float32)
    lo, hi = _random_intervals(rng, 5, 4, contain_qp=False, qp=qp)
    ub = interval_upper_bound(jnp.asarray(qp)[:, None, :],
                              jnp.asarray(lo)[None, :, :],
                              jnp.asarray(hi)[None, :, :])
    assert np.all(np.asarray(ub) < 1.0)
    # and it still equals the max of the endpoint bounds (peak at nearer end)
    want = np.maximum(ref.ub_mult(qp[:, None, :], lo[None]),
                      ref.ub_mult(qp[:, None, :], hi[None]))
    np.testing.assert_allclose(np.asarray(ub), want, atol=2e-6)


def test_degenerate_interval_equals_point_bound(rng):
    """lo == hi: the interval bound collapses to the plain Eq. 13 bound."""
    qp = np.clip(rng.normal(0, 0.5, size=(8, 6)), -1, 1).astype(np.float32)
    lo, hi = _random_intervals(rng, 10, 6, degenerate=True)
    got = interval_upper_bound(jnp.asarray(qp)[:, None, :],
                               jnp.asarray(lo)[None], jnp.asarray(hi)[None])
    want = ref.ub_mult(qp[:, None, :].astype(np.float64), lo[None])
    # where qp falls exactly on the degenerate point the bound is 1 == ub_mult
    np.testing.assert_allclose(np.asarray(got), want, atol=3e-6)


@pytest.mark.parametrize("impl", ["jax", "pallas"])
def test_block_bound_dominates_members(impl, rng):
    """For any member dp with dp_p in [lo_p, hi_p] for all p, the block
    bound is >= the member's own pivot upper bound (Eq. 13 min over p)."""
    m, nb, p, members = 13, 11, 6, 40
    qp = np.clip(rng.normal(0, 0.5, size=(m, p)), -1, 1).astype(np.float32)
    lo, hi = _random_intervals(rng, nb, p)
    if impl == "jax":
        blk = np.asarray(kref.block_bounds(jnp.asarray(qp), jnp.asarray(lo),
                                           jnp.asarray(hi)))
        blk2 = np.stack([np.asarray(block_upper_bound(
            jnp.asarray(qp), jnp.asarray(lo[b]), jnp.asarray(hi[b])))
            for b in range(nb)], axis=1)
        np.testing.assert_allclose(blk, blk2, atol=1e-6)  # two jnp paths agree
    else:
        blk = np.asarray(bp_kernel(jnp.asarray(qp), jnp.asarray(lo),
                                   jnp.asarray(hi), bm=8, bb=8,
                                   interpret=True))
    for _ in range(members):
        frac = rng.uniform(size=(nb, p)).astype(np.float32)
        dp = lo + frac * (hi - lo)                       # member inside block
        member_ub = np.min(ref.ub_mult(qp[:, None, :], dp[None]), axis=-1)
        assert np.all(blk + 1e-5 >= member_ub), (
            f"{impl}: block bound fails to dominate a member bound")


def test_block_bound_empty_padded_block(rng):
    """A fully-padded block carries the neutral [0, 0] interval from
    build_index; its bound must stay finite and valid (rows are masked, so
    any finite value is safe — but NaN/inf would poison the scan)."""
    qp = np.clip(rng.normal(0, 0.5, size=(4, 3)), -1, 1).astype(np.float32)
    lo = np.zeros((2, 3), np.float32)
    hi = np.zeros((2, 3), np.float32)
    for fn in (lambda: kref.block_bounds(jnp.asarray(qp), jnp.asarray(lo),
                                         jnp.asarray(hi)),
               lambda: bp_kernel(jnp.asarray(qp), jnp.asarray(lo),
                                 jnp.asarray(hi), bm=8, bb=8, interpret=True)):
        out = np.asarray(fn())
        assert np.all(np.isfinite(out))
        # neutral interval at 0: bound = min_p ub_mult(qp_p, 0) <= 1
        want = np.min(ref.ub_mult(qp, 0.0), axis=-1)
        np.testing.assert_allclose(out, np.broadcast_to(want[:, None],
                                                        out.shape), atol=2e-6)


@pytest.mark.parametrize("impl", ["jax", "pallas"])
def test_jax_and_pallas_agree_random(impl, rng):
    """Cross-check both implementations on a randomized sweep (the Pallas
    kernel pads M/NB internally; shapes chosen to exercise that)."""
    for m, nb, p in [(3, 5, 2), (17, 9, 7), (33, 40, 16)]:
        qp = np.clip(rng.normal(0, 0.5, size=(m, p)), -1, 1).astype(np.float32)
        lo, hi = _random_intervals(rng, nb, p)
        want = np.asarray(kref.block_bounds(jnp.asarray(qp), jnp.asarray(lo),
                                            jnp.asarray(hi)))
        if impl == "pallas":
            got = np.asarray(bp_kernel(jnp.asarray(qp), jnp.asarray(lo),
                                       jnp.asarray(hi), bm=16, bb=16,
                                       interpret=True))
            np.testing.assert_allclose(got, want, atol=1e-5)
