"""Multi-host sharded search (DESIGN.md §3.7): the process-local build +
cross-process τ/top-k merges, driven end to end by tools/multiprocess_smoke.py
— 2 worker processes (jax.distributed.initialize, gloo CPU collectives) x 2
virtual devices each, asserted bit-identical to the single-process sharded
backend and brute force inside the workers.  Kept small here (the CI
multiprocess job runs the full 2x4 shape); subprocesses because the main
test process must keep exactly one device (conftest.py)."""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")
SMOKE = os.path.join(REPO, "tools", "multiprocess_smoke.py")


def test_multiprocess_smoke_bit_identical():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)   # the launcher sets per-subprocess counts
    out = subprocess.run(
        [sys.executable, SMOKE, "--processes", "2", "--devices", "2",
         "--rows", "603", "--dim", "16", "--queries", "5",
         "--block-size", "32", "--pivots", "8"],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "multiprocess smoke ok" in out.stdout


def test_multiprocess_permuted_axes_ownership():
    """Permuted axis_names on a 2-axis mesh: P(("y","x")) flattens shards
    differently from mesh.devices, making each process's owned shard ids
    NON-contiguous (process 0 owns {0, 2} on a 2x2 mesh).  Ownership is
    read off the placement sharding's own index map, so the distributed
    build must still bake correct global row ids — regression for a
    devices.flat-order assumption that silently scrambled shard contents."""
    worker = """
        import sys
        pid, nproc, port = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
        sys.path.insert(0, {src!r})
        from repro.dist.compat import multiprocess_cpu_init
        multiprocess_cpu_init(f"127.0.0.1:{{port}}", nproc, pid)
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ref
        from repro.core.distributed import local_shard_rows
        from repro.search import SearchEngine
        rng = np.random.default_rng(2)
        db = ref.normalize(rng.normal(size=(211, 12))).astype(np.float32)
        mesh = jax.make_mesh((2, 2), ("x", "y"))
        _, owned = local_shard_rows(211, mesh, axis_names=("y", "x"))
        if pid == 0:
            assert [s for s, _, _ in owned] == [0, 2], owned
        local = np.concatenate([db[a:b] for _, a, b in owned])
        eng = SearchEngine.build(local, mesh=mesh, distributed=True,
                                 global_rows=211, axis_names=("y", "x"),
                                 n_pivots=4, block_size=16)
        s, i, _ = eng.search(jnp.asarray(db[:3]), 5)
        sref, iref = ref.brute_force_knn(db[:3], db, 5)
        assert np.allclose(np.asarray(s), sref, atol=3e-5)
        assert (np.sort(np.asarray(i), 1) == np.sort(iref, 1)).all()
        print("ok")
    """
    import socket
    import textwrap
    src = os.path.abspath(os.path.join(REPO, "src"))
    code = textwrap.dedent(worker).format(src=src)
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    procs = [subprocess.Popen(
        [sys.executable, "-c", code, str(i), "2", str(port)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env) for i in range(2)]
    outs = [p.communicate(timeout=600)[0] for p in procs]
    for p, out in zip(procs, outs):
        assert p.returncode == 0, out
        assert "ok" in out


def test_multiprocess_online_placement_agreement():
    """2 processes x 2 devices replay the same insert/delete/reoptimize
    sequence on a distributed-build engine.  Placement is a pure function
    of replicated host state (DESIGN.md §3.10) — each process prints a
    digest of its OWN host-side id -> (shard, slot) mirror, decided with
    zero extra collectives, and the digests must match across processes.
    Post-mutation results must also match the fp64 brute oracle on the
    live set."""
    worker = """
        import sys
        pid, nproc, port = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
        sys.path.insert(0, {src!r})
        from repro.dist.compat import multiprocess_cpu_init
        multiprocess_cpu_init(f"127.0.0.1:{{port}}", nproc, pid)
        import hashlib
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ref
        from repro.core.distributed import local_shard_rows
        from repro.search import SearchEngine
        rng = np.random.default_rng(5)
        db = ref.normalize(rng.normal(size=(211, 12))).astype(np.float32)
        mesh = jax.make_mesh((4,), ("data",))
        _, owned = local_shard_rows(211, mesh)
        local = np.concatenate([db[a:b] for _, a, b in owned])
        eng = SearchEngine.build(local, mesh=mesh, distributed=True,
                                 global_rows=211, n_pivots=4, block_size=16)
        h = eng.online(auto_reoptimize=False)
        new = ref.normalize(rng.normal(size=(60, 12))).astype(np.float32)
        live = {{i: db[i] for i in range(211)}}
        for i_, r in zip(h.insert(new[:7]), new[:7]):
            live[i_] = r
        dead = list(range(0, 30, 3))
        h.delete(dead)
        for x in dead:
            del live[x]
        # 53 rows > the free lists: appends one block on every shard
        for i_, r in zip(h.insert(new[7:]), new[7:]):
            live[i_] = r
        h.reoptimize()
        extra = ref.normalize(rng.normal(size=(3, 12))).astype(np.float32)
        for i_, r in zip(h.insert(extra), extra):
            live[i_] = r
        digest = hashlib.sha256(
            str(sorted(h._id_pos.items())).encode()).hexdigest()
        live_ids = np.array(sorted(live))
        rows_live = np.stack([live[int(x)] for x in live_ids])
        s, i, _ = eng.search(jnp.asarray(db[:3]), 5)
        sref, iref = ref.brute_force_knn(db[:3], rows_live, 5)
        assert np.allclose(np.asarray(s), sref, atol=3e-5)
        assert (np.sort(np.asarray(i), 1)
                == np.sort(live_ids[iref], 1)).all()
        print("digest", digest, flush=True)
        print("ok")
    """
    import socket
    import textwrap
    src = os.path.abspath(os.path.join(REPO, "src"))
    code = textwrap.dedent(worker).format(src=src)
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    procs = [subprocess.Popen(
        [sys.executable, "-c", code, str(i), "2", str(port)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env) for i in range(2)]
    outs = [p.communicate(timeout=600)[0] for p in procs]
    digests = []
    for p, out in zip(procs, outs):
        assert p.returncode == 0, out
        assert "ok" in out
        digests += [ln.split()[1] for ln in out.splitlines()
                    if ln.startswith("digest ")]
    assert len(digests) == 2 and digests[0] == digests[1], digests


def test_local_shard_rows_covers_datastore():
    """Single-process: the ownership helper tiles [0, n) exactly once, with
    the trailing short shard clamped."""
    import jax

    from repro.core.distributed import local_shard_rows
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    per, owned = local_shard_rows(101, mesh)
    assert per == -(-101 // jax.device_count())
    spans = sorted((start, stop) for _, start, stop in owned)
    assert spans[0][0] == 0 and spans[-1][1] == 101
    for (_, stop_a), (start_b, _) in zip(spans, spans[1:]):
        assert stop_a == start_b


def test_build_local_matches_single_controller():
    """Single-process equivalence: build_sharded_index_local on the full
    rows reproduces build_sharded_index leaf-for-leaf (same per-shard
    builder), so the multi-host path's shards are bit-identical by
    construction."""
    import jax

    from repro.core.distributed import (build_sharded_index,
                                        build_sharded_index_local,
                                        place_sharded_index)
    rng = np.random.default_rng(3)
    db = rng.normal(size=(203, 12)).astype(np.float32)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    a = place_sharded_index(
        build_sharded_index(db, mesh.devices.size, n_pivots=4, block_size=16),
        mesh)
    b = build_sharded_index_local(db, mesh, global_rows=203, n_pivots=4,
                                  block_size=16)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_build_local_rejects_wrong_slice():
    import jax

    from repro.core.distributed import build_sharded_index_local
    rng = np.random.default_rng(4)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    with pytest.raises(ValueError, match="local_shard_rows"):
        build_sharded_index_local(
            rng.normal(size=(50, 8)).astype(np.float32), mesh,
            global_rows=203, n_pivots=4, block_size=16)
