"""repro-lint selftest: every rule against its fixture corpus.

Fixtures live in tools/lint/selftest/ (see its README for the marker
conventions).  Each fixture is linted under its declared *virtual* path
so path-scoped rules fire; the harness asserts the exact
``(line, rule)`` finding set — positives must fire, everything else
must stay silent, and suppression comments must route findings to the
suppressed list.  No jax import anywhere in this file: the linter is
stdlib-only by design and these tests must stay cheap.
"""
from __future__ import annotations

import json
import re
import subprocess
import sys
from pathlib import Path

import pytest

from tools.lint import all_rules, lint_source, load_baseline
from tools.lint import cli as lint_cli

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tools" / "lint" / "selftest"
FIXTURE_FILES = sorted(FIXTURES.glob("*.py"))

_PATH_RE = re.compile(r"#\s*lint-fixture-path:\s*(\S+)")
_EXPECT_RE = re.compile(r"#\s*EXPECT:\s*([A-Z0-9]+)")
_EXPECT_SUP_RE = re.compile(r"#\s*EXPECT-SUPPRESSED:\s*([A-Z0-9]+)")

RULE_IDS = ("R001", "R002", "R003", "R004",
            "R005", "R006", "R007", "R008")


def _load(path: Path):
    src = path.read_text()
    m = _PATH_RE.search(src)
    assert m, f"{path.name}: missing '# lint-fixture-path:' header"
    expected, expected_sup = set(), set()
    for i, line in enumerate(src.splitlines(), 1):
        expected.update((i, r) for r in _EXPECT_RE.findall(line))
        expected_sup.update((i, r) for r in _EXPECT_SUP_RE.findall(line))
    return src, m.group(1), expected, expected_sup


# ---------------------------------------------------------------------------
# the corpus: exact finding sets, positive and negative cases per rule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", FIXTURE_FILES, ids=lambda p: p.stem)
def test_fixture_findings_exact(fixture):
    src, vpath, expected, expected_sup = _load(fixture)
    ctx = lint_source(src, vpath)
    got = {(f.line, f.rule) for f in ctx.findings}
    assert got == expected, (
        f"{fixture.name} (as {vpath}):\n"
        f"  unexpected: {sorted(got - expected)}\n"
        f"  missing:    {sorted(expected - got)}\n"
        f"  findings:\n    " + "\n    ".join(map(str, ctx.findings)))
    got_sup = {(f.line, f.rule) for f in ctx.suppressed}
    assert got_sup == expected_sup, (
        f"{fixture.name}: suppression mismatch — "
        f"got {sorted(got_sup)}, want {sorted(expected_sup)}")


def test_every_rule_has_positive_and_suppressed_case():
    fired, suppressed = set(), set()
    for f in FIXTURE_FILES:
        _, _, expected, expected_sup = _load(f)
        fired.update(r for _, r in expected)
        suppressed.update(r for _, r in expected_sup)
    assert fired == set(RULE_IDS), f"rules without a failing fixture: " \
                                   f"{set(RULE_IDS) - fired}"
    assert suppressed == set(RULE_IDS), \
        f"rules without a suppression fixture: {set(RULE_IDS) - suppressed}"


def test_every_rule_has_negative_coverage():
    # each rule's fixtures contain clean constructs adjacent to the dirty
    # ones: at least one fixture file that exercises the rule's territory
    # with ZERO expected findings for it on some lines — approximated by
    # requiring every fixture to contain non-flagged lines of code
    for f in FIXTURE_FILES:
        src, vpath, expected, _ = _load(f)
        code_lines = [i for i, ln in enumerate(src.splitlines(), 1)
                      if ln.strip() and not ln.strip().startswith("#")]
        flagged = {i for i, _ in expected}
        assert set(code_lines) - flagged, \
            f"{f.name}: no negative (clean) lines at all"


def test_registry_is_complete_and_documented():
    rules = all_rules()
    assert [r.id for r in rules] == sorted(r.id for r in rules)
    assert {r.id for r in rules} >= set(RULE_IDS)
    for r in rules:
        assert r.title, f"{r.id}: empty title"
        assert r.provenance, f"{r.id}: empty provenance"
        assert (r.__doc__ or "").strip(), f"{r.id}: missing docstring"


def test_syntax_error_becomes_finding():
    ctx = lint_source("def broken(:\n", "scratch/broken.py")
    assert [f.rule for f in ctx.findings] == ["E000"]


# ---------------------------------------------------------------------------
# the live tree is clean against the (empty) committed baseline
# ---------------------------------------------------------------------------

def test_live_tree_clean():
    findings = lint_cli.run_repro_lint(REPO, list(lint_cli.DEFAULT_PATHS))
    baseline = load_baseline(REPO / lint_cli.BASELINE)
    fresh = [f for f in findings if f.key not in baseline]
    assert not fresh, "live-tree findings:\n" + "\n".join(map(str, fresh))


def test_committed_baseline_is_empty():
    # the burn-down contract: ISSUE 10 ships the baseline at zero; a PR
    # that wants to grandfather a finding must change this test too
    assert load_baseline(REPO / lint_cli.BASELINE) == set()


def test_fixture_corpus_is_excluded_from_live_scan():
    files = lint_cli.iter_python_files(REPO, list(lint_cli.DEFAULT_PATHS))
    assert not [f for f in files if "selftest" in f.parts]


# ---------------------------------------------------------------------------
# CLI: exit codes, --json shape, seeded violation fails the gate
# ---------------------------------------------------------------------------

def _run_cli(*args):
    return subprocess.run(  # repro-lint: disable=R003  (stdlib-only tool)
        [sys.executable, "-m", "tools.lint", *args],
        cwd=REPO, capture_output=True, text=True,
        env={"PATH": "/usr/bin:/bin", "PYTHONPATH": "src"},
    )


def test_cli_clean_tree_exits_zero():
    proc = _run_cli("--json", "--no-ruff")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["findings"] == []
    assert payload["stale_baseline"] == []


def test_cli_seeded_violation_fails(tmp_path):
    # the acceptance-criteria scenario: a raw top_k slice in a scratch
    # file must fail the gate
    bad = tmp_path / "scratch_seeded.py"
    bad.write_text(
        "import jax\n\n"
        "def warm(scores, k):\n"
        "    return jax.lax.top_k(scores, k)[0][:, -1]\n")
    rc = lint_cli.main([str(bad), "--no-ruff"])
    assert rc == 1
    findings = lint_cli.run_repro_lint(REPO, [str(bad)])
    assert [(f.line, f.rule) for f in findings] == [(4, "R001")]


def test_cli_list_rules():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    for rid in RULE_IDS:
        assert rid in proc.stdout


def test_cli_require_ruff_fails_when_missing(tmp_path, monkeypatch):
    import shutil as _shutil
    monkeypatch.setattr(_shutil, "which", lambda name: None)
    rc, note = lint_cli.run_ruff(REPO, ["src"], require=True)
    assert rc == 1 and "REQUIRED" in note
    rc, note = lint_cli.run_ruff(REPO, ["src"], require=False)
    assert rc == 0 and "skipped" in note
