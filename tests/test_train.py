"""Trainer: learning works, checkpoint/restart is exact, stragglers are
detected, gradient compression preserves convergence (error feedback)."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.data.pipeline import SyntheticLM
from repro.models import model_fns
from repro.optim import compression
from repro.train.train_step import init_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def _setup(tmp, total=14, ckpt_every=5, arch="tinyllama-1.1b", **step_kw):
    cfg = smoke_config(arch).replace(n_layers=2, d_model=32, d_ff=64,
                                     n_heads=2, n_kv_heads=2, d_head=16,
                                     vocab=64)
    fns = model_fns(cfg)
    step = jax.jit(make_train_step(fns, cfg, **step_kw))
    state = init_state(fns, jax.random.PRNGKey(0),
                       compress_grads=step_kw.get("compress_grads", False))
    data = SyntheticLM(cfg.vocab, 16, 8, seed=1)
    tc = TrainerConfig(total_steps=total, ckpt_every=ckpt_every,
                       ckpt_dir=os.path.join(tmp, "ckpt"), log_every=100)
    return Trainer(step, state, data, tc), cfg


def test_loss_decreases(tmp_path):
    tr, _ = _setup(str(tmp_path), total=30)
    out = tr.run(install_signal=False)
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first, (first, last)


def test_checkpoint_restart_exact(tmp_path):
    # run 1: stop "crashed" at step 9 (last ckpt at 5... plus final at 9)
    tr1, _ = _setup(str(tmp_path), total=9)
    out1 = tr1.run(install_signal=False)
    losses1 = {h["step"]: h["loss"] for h in out1["history"]}
    # continue to 14 in a fresh trainer (simulated restart)
    tr2, _ = _setup(str(tmp_path), total=14)
    out2 = tr2.run(install_signal=False)
    assert out2["final_step"] == 14
    assert out2["history"][0]["step"] == 10, "resumed from checkpoint"
    # reference: uninterrupted run in a different dir
    tr3, _ = _setup(str(tmp_path) + "_ref", total=14)
    out3 = tr3.run(install_signal=False)
    ref = {h["step"]: h["loss"] for h in out3["history"]}
    for h in out2["history"]:
        assert abs(h["loss"] - ref[h["step"]]) < 1e-4, h["step"]


def test_straggler_watchdog(tmp_path):
    tr, _ = _setup(str(tmp_path), total=12, ckpt_every=50)
    import time
    orig = tr.train_step
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 8:
            # injected straggler, scaled to the live step time so the test is
            # robust to a loaded host
            time.sleep(max(1.0, 5.0 * (tr._ema or 0.2)))
        return orig(state, batch)

    tr.train_step = slow_step
    out = tr.run(install_signal=False)
    # the 8th call is step index 7 (pre-increment)
    assert any(6 <= s <= 9 for s in out["stragglers"]), out["stragglers"]


def test_grad_compression_error_feedback(tmp_path):
    tr, _ = _setup(str(tmp_path), total=25, ckpt_every=100,
                   compress_grads=True)
    out = tr.run(install_signal=False)
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first, "int8+EF training still converges"


def test_compression_error_feedback_bounded(rng):
    """EF property: accumulated residual stays bounded over many steps."""
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    err = jnp.zeros_like(g)
    for _ in range(50):
        deq, err = compression.compress_tree(g, err)
        # per-step: deq + err == g + old_err (no signal lost)
    assert float(jnp.abs(err).max()) < float(jnp.abs(g).max()) * 0.05


def test_accum_matches_single_batch(tmp_path):
    """Gradient accumulation == one big batch (same loss trajectory)."""
    cfg = smoke_config("tinyllama-1.1b").replace(
        n_layers=2, d_model=32, d_ff=64, n_heads=2, n_kv_heads=2, d_head=16,
        vocab=64)
    fns = model_fns(cfg)
    from repro.models import synthetic_batch
    batch = synthetic_batch(cfg, 8, 16)
    s1 = init_state(fns, jax.random.PRNGKey(0))
    s2 = jax.tree.map(lambda x: x, s1)
    f1 = jax.jit(make_train_step(fns, cfg, accum=1))
    f4 = jax.jit(make_train_step(fns, cfg, accum=4))
    s1, m1 = f1(s1, batch)
    s2, m2 = f4(s2, batch)
    # mean loss over microbatches differs from big-batch loss by batch-norm
    # effects only through the metrics; grads averaged -> params match closely
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     s1["params"], s2["params"])
    assert max(jax.tree.leaves(d)) < 5e-3
