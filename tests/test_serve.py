"""Serving engine + kNN-LM: cached decode equals teacher forcing; the
datastore measurably shifts next-token probabilities toward neighbors."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import model_fns, synthetic_batch
from repro.serve.engine import Engine
from repro.serve.knnlm import KNNDatastore


def _tiny(arch="tinyllama-1.1b"):
    cfg = smoke_config(arch).replace(dtype="float32")
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


def test_engine_prefill_then_decode_matches_forward():
    cfg, fns, params = _tiny()
    batch = synthetic_batch(cfg, 2, 10)
    eng = Engine(fns, params, max_seq=40)
    cache, clen, last_h = eng.prefill(batch)
    # teacher-forced forward over prompt gives the same last hidden
    h_full, _, _ = fns.forward(params, batch)
    np.testing.assert_allclose(np.asarray(last_h), np.asarray(h_full[:, -1]),
                               atol=2e-4)
    toks, _ = eng.decode(cache, clen, batch["tokens"][:, -1:], 5)
    assert toks.shape == (2, 5)
    assert int(toks.max()) < cfg.vocab


def test_engine_decode_logits_match_teacher_forcing():
    """First decode step's logits == teacher-forced last-position logits
    (argmax equality is fp-flaky when two logits tie; compare values)."""
    cfg, fns, params = _tiny()
    batch = synthetic_batch(cfg, 1, 8)
    eng = Engine(fns, params, max_seq=32)
    cache, clen, _ = eng.prefill(batch)
    # decode one token: feeds tokens[-1]... the cache already contains it, so
    # compare against forward over the prompt with the same last token twice
    ext = jnp.concatenate([batch["tokens"], batch["tokens"][:, -1:]], axis=1)
    h_ref, _, _ = fns.forward(params, {"tokens": ext})
    ref_logits = fns.lm_head(params, h_ref)[:, -1]
    _, logits, _ = eng._decode_jit(params, batch["tokens"][:, -1:], cache, clen)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=2e-3)
    # decode is deterministic
    t1, _ = eng.decode(cache, clen, batch["tokens"][:, -1:], 3)
    t2, _ = eng.decode(cache, clen, batch["tokens"][:, -1:], 3)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_knn_datastore_boosts_neighbor_tokens(rng):
    cfg, fns, params = _tiny()
    d = cfg.d_model
    # synthetic datastore: embeddings clustered around 3 prototypes, each
    # mapped to a distinct next-token
    protos = rng.normal(size=(3, d)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    embs, toks = [], []
    for i, t in enumerate([7, 11, 23]):
        e = protos[i] + 0.05 * rng.normal(size=(50, d)).astype(np.float32)
        embs.append(e)
        toks.extend([t] * 50)
    ds = KNNDatastore.from_pairs(np.concatenate(embs), np.array(toks),
                                 cfg.vocab, k=8, n_pivots=4, block_size=32)
    q = jnp.asarray(protos[1][None])
    probs = ds.knn_probs(q)
    assert int(jnp.argmax(probs[0])) == 11
    # interpolation moves LM probs toward the datastore token
    lm = jnp.full((1, cfg.vocab), 1.0 / cfg.vocab)
    mixed = ds.interpolate(q, lm, 0.5)
    assert float(mixed[0, 11]) > float(lm[0, 11])
    np.testing.assert_allclose(float(mixed.sum()), 1.0, atol=1e-5)


def test_batcher_survives_sequential_event_loops(rng):
    """Satellite regression (PR 9): ``submit`` lazily created the worker
    task on the first caller's event loop and never re-checked, so reusing
    a batcher across two sequential ``asyncio.run`` calls enqueued onto a
    dead loop and hung forever.  The batcher must now detect the loop
    change and re-create its worker + queue on the caller's loop."""
    import asyncio

    from repro.search import SearchEngine
    from repro.serve.frontend import ContinuousBatcher

    db = rng.normal(size=(128, 16)).astype(np.float32)
    eng = SearchEngine.build(db, n_pivots=4, block_size=32)
    batcher = ContinuousBatcher(eng, k=3, max_batch=4, max_wait_ms=1.0)

    async def one(i):
        sims, ids = await batcher.submit(db[i])
        assert int(ids[0]) == i          # own row is its own top hit
        assert sims.shape == (3,)

    async def round_trip(n):
        await asyncio.wait_for(
            asyncio.gather(*(one(i) for i in range(n))), timeout=60)

    asyncio.run(round_trip(5))
    # pre-fix this second run waits forever on the first (dead) loop's
    # queue; the wait_for turns the hang into a loud TimeoutError
    asyncio.run(round_trip(5))
    assert batcher.n_queries == 10
    asyncio.run(asyncio.wait_for(batcher.close(), timeout=60))


def test_knn_from_corpus_and_engine_integration():
    cfg, fns, params = _tiny()
    batches = [synthetic_batch(cfg, 2, 16, seed=s) for s in range(2)]
    ds = KNNDatastore.from_corpus(fns, params, batches, cfg.vocab, k=4,
                                  n_pivots=4, block_size=32)
    eng = Engine(fns, params, max_seq=32, knn=ds, lmbda=0.3)
    batch = synthetic_batch(cfg, 2, 8, seed=9)
    cache, clen, _ = eng.prefill(batch)
    toks, _ = eng.decode(cache, clen, batch["tokens"][:, -1:], 3)
    assert toks.shape == (2, 3)
    assert not np.isnan(np.asarray(toks)).any()
