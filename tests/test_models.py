"""Per-arch smoke tests (reduced configs) + internal equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import model_fns, synthetic_batch
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.config import MoEConfig
from repro.train.train_step import make_train_step, init_state

ALL_ARCHS = list(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = smoke_config(arch)
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, 2, 32)
    hidden, _, aux = fns.forward(params, batch)
    logits = fns.lm_head(params, hidden)
    off = cfg.vision_seq or 0
    assert hidden.shape == (2, 32 + off, cfg.d_model)
    assert logits.shape == (2, 32 + off, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    fns = model_fns(cfg)
    step_fn = jax.jit(make_train_step(fns, cfg))
    state = init_state(fns, jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, 2, 32)
    state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["grad_norm"]) > 0
    # one more step: loss stays finite, params actually changed
    state2, m2 = step_fn(state, batch)
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "granite-moe-1b-a400m",
                                  "zamba2-1.2b", "rwkv6-1.6b",
                                  "whisper-small", "mixtral-8x22b",
                                  "qwen2.5-14b"])
def test_decode_matches_prefill(arch):
    cfg = smoke_config(arch).replace(dtype="float32")
    if cfg.moe is not None:   # drop-free so teacher forcing == cached decode
        cfg = cfg.replace(moe=MoEConfig(n_experts=cfg.moe.n_experts,
                                        top_k=cfg.moe.top_k,
                                        capacity_factor=float(cfg.moe.n_experts)))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(1))
    T = 12
    batch = synthetic_batch(cfg, 2, T, seed=3)
    h_full, _, _ = fns.forward(params, batch)
    cache = fns.cache_init(params, batch, 2, 32)
    hs = []
    for t in range(T):
        h1, cache = fns.decode_step(params, batch["tokens"][:, t:t + 1],
                                    cache, jnp.int32(t))
        hs.append(h1)
    err = float(jnp.abs(h_full - jnp.concatenate(hs, 1)).max())
    assert err < 5e-3, f"{arch}: {err}"


def test_ssd_chunked_equals_recurrence(rng):
    b, s, h, p, n, g = 2, 37, 4, 8, 6, 2
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    y_c, st_c = ssm_mod._ssd_chunked(x, dt, A, B, C, chunk=8)
    rep = h // g
    Bh, Ch = jnp.repeat(B, rep, 2), jnp.repeat(C, rep, 2)
    st = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A[None])
        st = st * dA[..., None, None] + jnp.einsum(
            "bhn,bhp,bh->bhnp", Bh[:, t], x[:, t], dt[:, t])
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ch[:, t], st))
    np.testing.assert_allclose(y_c, jnp.stack(ys, 1), atol=1e-4)
    np.testing.assert_allclose(st_c, st, atol=1e-4)


def test_wkv_chunked_equals_scan(rng):
    b, s, h, m = 2, 50, 4, 8
    r, k, v = (jnp.asarray(rng.normal(size=(b, s, h, m)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.2, 0.999, size=(b, s, h, m)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, m)), jnp.float32)
    st0 = jnp.asarray(rng.normal(size=(b, h, m, m)), jnp.float32) * 0.1
    o1, s1 = rwkv_mod._wkv_scan(r, k, v, w, u, st0)
    o2, s2 = rwkv_mod._wkv_chunked(r, k, v, w, u, st0, chunk=16)
    np.testing.assert_allclose(o1, o2, atol=1e-4)
    np.testing.assert_allclose(s1, s2, atol=1e-4)


def test_flash_attention_matches_naive(rng):
    from repro.models.layers import flash_attention
    B, S, H, KV, Dh = 2, 40, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, Dh)), jnp.float32)
    for window in (None, 8):
        out = flash_attention(q, k, v, causal=True, window=window,
                              chunk_q=16, chunk_k=8)
        kg = jnp.repeat(k, H // KV, 2)
        vg = jnp.repeat(v, H // KV, 2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kg) / np.sqrt(Dh)
        dpos = jnp.arange(S)[:, None] - jnp.arange(S)[None, :]
        mask = dpos >= 0
        if window is not None:
            mask &= dpos < window
        s = jnp.where(mask[None, None], s, -jnp.inf)
        ref_out = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vg)
        np.testing.assert_allclose(out, ref_out, atol=2e-5,
                                   err_msg=f"window={window}")


def test_moe_no_drop_routing(rng):
    from repro.models.moe import moe_init, moe_apply
    cfg = smoke_config("mixtral-8x22b").replace(dtype="float32")
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y1, aux = moe_apply(p, x, cfg, no_drop=True)
    assert y1.shape == x.shape and np.isfinite(float(aux))
    # permutation invariance across the batch under no_drop
    perm = jnp.asarray([1, 0])
    y2, _ = moe_apply(p, x[perm], cfg, no_drop=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1[perm]), atol=2e-5)


def test_param_count_matches_tree():
    """Analytic param_count (used for roofline MODEL_FLOPS) agrees with the
    actual parameter tree."""
    import math
    for arch in ["tinyllama-1.1b", "granite-3-2b"]:
        cfg = ARCHS[arch]
        fns = model_fns(cfg)
        ab = jax.eval_shape(fns.init, jax.random.PRNGKey(0))
        n_tree = sum(math.prod(l.shape) for l in jax.tree.leaves(ab))
        n_analytic = cfg.param_count()
        assert abs(n_tree - n_analytic) / n_tree < 0.02, (arch, n_tree, n_analytic)
