"""Ring-buffer SWA cache: decode over a window-sized rolling cache must
equal decode over a full-length cache once masking is applied."""
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import model_fns, synthetic_batch
from repro.models.config import MoEConfig


def test_ring_cache_matches_full_cache():
    window = 8
    cfg = smoke_config("mixtral-8x22b").replace(
        dtype="float32", sliding_window=window,
        moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    T = 24          # decode well past the window so the ring wraps twice
    batch = synthetic_batch(cfg, 2, T, seed=1)

    # full cache: lm_cache_init clamps attn caches to the window when SWA is
    # set, so request a big max_seq with sliding_window=None to get a true
    # full cache, then run with the windowed config for masking.
    cfg_full = cfg.replace(sliding_window=None)
    fns_full = model_fns(cfg_full)
    cache_full = fns_full.cache_init(params, batch, 2, 64)
    # windowed masking over a full cache = flash path with window set; use a
    # config that has the window but a cache larger than it (non-ring path)
    cfg_big = cfg.replace(max_seq_len=64)
    fns_big = model_fns(cfg_big)
    cache_big = fns_big.cache_init(params, batch, 2, 64)
    # NOTE: _block_cache_init clamps to window -> Smax == window == ring.
    # To force the non-ring reference, build the cache by hand with Smax=64.
    import repro.models.lm as lm_mod
    ref_cache = []
    for btype, count in lm_mod._runs(cfg):
        one = {
            "attn": {
                "k": jnp.zeros((count, 2, 64, cfg.n_kv_heads * cfg.kv_repeat,
                                cfg.head_dim), jnp.float32),
                "v": jnp.zeros((count, 2, 64, cfg.n_kv_heads * cfg.kv_repeat,
                                cfg.head_dim), jnp.float32),
            }
        }
        ref_cache.append(jax.tree.map(lambda a: a, one))

    ring_cache = fns.cache_init(params, batch, 2, 32)   # clamps to window=8
    # sanity: the ring cache really is window-sized
    k_shape = jax.tree.leaves(ring_cache)[0].shape
    assert window in k_shape, k_shape

    outs_ring, outs_ref = [], []
    c_ring, c_ref = ring_cache, ref_cache
    for t in range(T):
        tok = batch["tokens"][:, t:t + 1]
        h_ring, c_ring = fns.decode_step(params, tok, c_ring, jnp.int32(t))
        h_ref, c_ref = fns.decode_step(params, tok, c_ref, jnp.int32(t))
        outs_ring.append(h_ring)
        outs_ref.append(h_ref)
    r = jnp.concatenate(outs_ring, 1)
    f = jnp.concatenate(outs_ref, 1)
    err = float(jnp.abs(r - f).max())
    assert err < 5e-3, f"ring vs full-window decode mismatch: {err}"
