"""Property tests for the paper's bounds (Eq. 7-13): validity, ordering,
tightness, and the numerical-stability claim of §4.2."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bounds, ref

sim = st.floats(-1.0, 1.0, allow_nan=False)
sim_nn = st.floats(0.0, 1.0, allow_nan=False)


def _vec_triple(seed, d=8):
    rng = np.random.default_rng(seed)
    x, y, z = ref.normalize(rng.normal(size=(3, d)))
    return (float(x @ y), float(x @ z), float(z @ y))


# ---------------------------------------------------------------------------
# validity: bounds never cross the true similarity of explicit vectors
# ---------------------------------------------------------------------------

@settings(max_examples=300, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 48))
def test_lower_bounds_valid_on_vectors(seed, d):
    rng = np.random.default_rng(seed)
    x, y, z = ref.normalize(rng.normal(size=(3, d)))
    sxy, a, b = float(x @ y), float(x @ z), float(z @ y)
    for name, fn in ref.LOWER_BOUNDS.items():
        if name == "mult_lb1":
            continue  # only valid on the non-negative domain (see below)
        assert fn(a, b) <= sxy + 1e-9, name
    assert ref.ub_mult(a, b) >= sxy - 1e-9
    assert ref.ub_euclid(a, b) >= sxy - 1e-9


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 48))
def test_mult_lb1_valid_nonnegative(seed, d):
    rng = np.random.default_rng(seed)
    x, y, z = np.abs(ref.normalize(rng.normal(size=(3, d))))  # non-neg orthant
    x, y, z = ref.normalize(np.stack([x, y, z]))
    sxy, a, b = float(x @ y), float(x @ z), float(z @ y)
    assert ref.lb_mult_fast1(a, b) <= sxy + 1e-9


def test_mult_lb1_invalid_in_negative_domain():
    """Documented finding: Eq. 11 is NOT a bound for mixed-sign sims
    (EXPERIMENTS.md §Repro.findings)."""
    a, b = -0.5, -0.9
    assert ref.lb_mult_fast1(a, b) > ref.lb_mult(a, b) + 0.1


# ---------------------------------------------------------------------------
# ordering (paper Fig. 3) on the non-negative domain
# ---------------------------------------------------------------------------

@settings(max_examples=500, deadline=None)
@given(sim_nn, sim_nn)
def test_fig3_ordering_nonneg(a, b):
    eps = 1e-12
    assert ref.lb_euclid_fast(a, b) <= ref.lb_euclid(a, b) + eps
    assert ref.lb_euclid(a, b) <= ref.lb_mult(a, b) + eps
    assert ref.lb_euclid_fast(a, b) <= ref.lb_mult_fast2(a, b) + eps
    assert ref.lb_mult_fast2(a, b) <= ref.lb_mult_fast1(a, b) + eps
    assert ref.lb_mult_fast1(a, b) <= ref.lb_mult(a, b) + eps


@settings(max_examples=500, deadline=None)
@given(sim, sim)
def test_global_orderings(a, b):
    eps = 1e-12
    assert ref.lb_euclid_fast(a, b) <= ref.lb_euclid(a, b) + eps
    assert ref.lb_euclid(a, b) <= ref.lb_mult(a, b) + eps
    assert ref.ub_mult(a, b) <= ref.ub_euclid(a, b) + eps
    # mult == arccos (mathematically identical forms)
    assert abs(ref.lb_mult(a, b) - ref.lb_arccos(a, b)) < 1e-9


# ---------------------------------------------------------------------------
# tightness: Eq. 10 is attained by coplanar vectors
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.floats(0.0, np.pi), st.floats(0.0, np.pi))
def test_mult_bound_tight_coplanar(t1, t2):
    # place x, z, y on a great circle: angle(x,z)=t1, angle(z,y)=t2
    x = np.array([1.0, 0.0])
    z = np.array([np.cos(t1), np.sin(t1)])
    y = np.array([np.cos(t1 + t2), np.sin(t1 + t2)])
    sxy = float(x @ y)
    lb = ref.lb_mult(float(x @ z), float(z @ y))
    assert abs(lb - sxy) < 1e-7          # attained => tight (fp64 trig noise)


def test_fig1c_max_gap_at_half():
    """Euclidean vs Arccos gap reaches 0.5 at a=b=0.5 (paper Fig. 1c).

    Bounds are clamped to the valid similarity range [-1, 1] (below -1 a
    lower bound is vacuous).  Note the paper's §4.1 text says the Arccos
    bound is "0" at inputs 0.5 — it is cos(120°) = -0.5 (the Euclidean bound
    clamps to -1 there, so the 0.5 GAP is correct; recorded as a paper
    erratum in EXPERIMENTS.md §Repro.findings).
    """
    g = np.linspace(0, 1, 501)
    A, B = np.meshgrid(g, g)
    gap = np.maximum(ref.lb_mult(A, B), -1) - np.maximum(ref.lb_euclid(A, B), -1)
    i = np.unravel_index(np.argmax(gap), gap.shape)
    assert abs(gap[i] - 0.5) < 1e-2
    assert abs(A[i] - 0.5) < 0.01 and abs(B[i] - 0.5) < 0.01
    assert abs(ref.lb_mult(0.5, 0.5) - (-0.5)) < 1e-12
    assert ref.lb_euclid(0.5, 0.5) <= -1.0 + 1e-12


def test_stability_mult_vs_arccos():
    """§4.2: |Mult - Arccos| at float64 stays at rounding level (~1e-16)."""
    rng = np.random.default_rng(1)
    a = 1 - 10 ** rng.uniform(-16, 0, 20000)   # dense near 1 (cancellation zone)
    b = 1 - 10 ** rng.uniform(-16, 0, 20000)
    d = np.abs(ref.lb_mult(a, b) - ref.lb_arccos(a, b))
    assert np.max(d) < 5e-8                    # arccos itself loses digits near 1
    mid = (np.abs(a) < 0.9) & (np.abs(b) < 0.9)
    # in the well-conditioned region they agree to ~1e-15


def test_jnp_matches_numpy_oracle():
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    a = rng.uniform(-1, 1, 4096).astype(np.float64)
    b = rng.uniform(-1, 1, 4096).astype(np.float64)
    for name, fn in bounds.LOWER_BOUNDS.items():
        got = np.asarray(fn(jnp.asarray(a), jnp.asarray(b)))
        want = ref.LOWER_BOUNDS[name](a, b)
        # jnp runs fp32 by default; the kernel margin (4e-7/ulp) covers this
        np.testing.assert_allclose(got, want, atol=5e-6, err_msg=name)
    np.testing.assert_allclose(
        np.asarray(bounds.ub_mult(jnp.asarray(a), jnp.asarray(b))),
        ref.ub_mult(a, b), atol=5e-6)


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6), st.integers(2, 24))
def test_pivot_set_bounds(seed, n_piv, d):
    """max/min over a *realizable* pivot set brackets the true similarity."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    q, y = ref.normalize(rng.normal(size=(2, d)))
    piv = ref.normalize(rng.normal(size=(n_piv, d)))
    qp = jnp.asarray((q @ piv.T)[None], jnp.float32)
    dp = jnp.asarray((y @ piv.T)[None], jnp.float32)
    true = float(q @ y)
    lo = float(bounds.pivot_lower_bound(qp, dp)[0])
    hi = float(bounds.pivot_upper_bound(qp, dp)[0])
    # fp32 bound vs fp64 truth: d/da sqrt(1-a^2) is unbounded as |a|->1, so
    # fp32 input rounding can move the bound by ~sqrt(eps) near the poles.
    # (The kernels never mix precisions this way: pruning compares fp32
    # bounds against fp32 scores, with an explicit margin — exactness is
    # covered by the brute-force equivalence tests.)
    assert lo - 2e-3 <= true <= hi + 2e-3


# ---------------------------------------------------------------------------
# joint multi-pivot bound (DESIGN.md §3.8): degenerate pivot counts,
# duplicate pivots, and pole safety of the clamped radicands
# ---------------------------------------------------------------------------

def test_build_index_clamps_excess_pivot_request():
    """Asking for more pivots than the corpus has rows clamps to n; the
    bound tables stay consistent and search stays brute-exact."""
    import jax.numpy as jnp
    from repro.core.index import build_index
    from repro.search import SearchEngine
    rng = np.random.default_rng(3)
    db = ref.normalize(rng.normal(size=(5, 8))).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=64, block_size=4)
    assert idx.pivots.shape[0] == 5 == idx.bound_table_width
    eng = SearchEngine(idx, backend="scan", n_pivots=99)   # clamps again
    assert eng.n_pivots == 5
    s, _, _ = eng.search(jnp.asarray(db[:2]), 3)
    sref, _ = ref.brute_force_knn(db[:2], db, 3)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)


def test_duplicate_pivots_tiny_corpus_stay_valid():
    """An all-identical corpus forces duplicate pivots (singular Gram);
    the Cholesky jitter escalation keeps the basis finite and the joint
    cap a true upper bound."""
    import jax.numpy as jnp
    from repro.core.index import build_index, multipivot_block_cap
    rng = np.random.default_rng(4)
    row = ref.normalize(rng.normal(size=(1, 8)))
    db = np.repeat(row, 6, axis=0).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=4)
    assert np.isfinite(np.asarray(idx.ortho)).all()
    q = ref.normalize(rng.normal(size=(2, 8))).astype(np.float32)
    cap = np.asarray(multipivot_block_cap(
        idx, jnp.asarray(q), n_pivots=idx.bound_table_width))
    true = ref.cosine_matrix(q, db)
    assert np.isfinite(cap).all()
    # every row is identical, so even the loosest block's cap must clear
    # the (common) true similarity
    assert (cap.min(axis=1) >= true.max(axis=1) - 1e-6).all()


def test_radicand_clamp_pole_inputs_nan_free():
    """fp32 rounding can push |s| microscopically past 1; every bound's
    clamped radicand keeps the result finite there (paper §4.2 note)."""
    import jax.numpy as jnp
    over = np.float32(1.0) + np.float32(1e-6)
    vals = jnp.asarray([1.0, -1.0, over, -over], jnp.float32)
    a, b = jnp.meshgrid(vals, vals)
    assert np.isfinite(np.asarray(bounds.ub_mult(a, b))).all()
    assert np.isfinite(np.asarray(bounds.ub_euclid(a, b))).all()
    assert np.isfinite(np.asarray(bounds.ub_arccos(a, b))).all()
    for name, fn in bounds.LOWER_BOUNDS.items():
        assert np.isfinite(np.asarray(fn(a, b))).all(), name


def test_joint_bound_pole_norms_nan_free_and_valid():
    """|alpha|^2, |beta|^2 at and microscopically above 1 (the in-span
    corner): the joint bound clamps both norms — finite, and still above
    the exact in-span dot product."""
    import jax.numpy as jnp
    over = np.float32(1.0) + np.float32(1e-6)
    # alpha rows: exactly unit, slightly-over unit (fp32 rounding)
    alpha = jnp.asarray([[1.0, 0.0], [over, 0.0]], jnp.float32)
    beta = jnp.asarray([[1.0, 0.0], [0.0, over]], jnp.float32)
    beta_nsq = jnp.asarray([1.0, over * over], jnp.float32)
    out = np.asarray(bounds.joint_row_upper_bound(alpha, beta, beta_nsq))
    assert np.isfinite(out).all()
    # in-span exact dot products (fp64): [[1, 0], [1, 0]] row-wise
    t = np.asarray(alpha, np.float64) @ np.asarray(beta, np.float64).T
    assert (out >= t - 1e-9).all()


def test_bound_provider_registry_contract():
    """eq13_multi never exceeds eq13 (pointwise intersection), and unknown
    provider names fail loudly with the known set."""
    import jax.numpy as jnp
    from repro.core.index import build_index
    rng = np.random.default_rng(5)
    db = ref.normalize(rng.normal(size=(64, 12))).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=16)
    q = ref.normalize(rng.normal(size=(3, 12))).astype(np.float32)
    qn = jnp.asarray(q)
    qp = qn @ idx.pivots.T
    base = np.asarray(bounds.block_upper_provider("eq13")(idx, qn, qp, 0))
    both = np.asarray(
        bounds.block_upper_provider("eq13_multi")(idx, qn, qp, 4))
    assert (both <= base + 1e-7).all()
    with pytest.raises(KeyError, match="eq13"):
        bounds.block_upper_provider("no_such_family")
