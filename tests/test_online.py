"""Online mutation (DESIGN.md §3.9): random interleavings of
insert/delete/search stay tie-aware brute-equal on the *live* corpus for
every backend, through the block-tail-full -> new-block transition and
across full reoptimizes.  The correctness argument under test is
conservative widening: inserts only loosen intervals (bounds stay true
upper bounds), tombstones mask per row before top-k."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.search import SearchEngine

BACKENDS = ["scan", "brute", "tree", "kernel"]
ATOL = 3e-5


def _norm64(x):
    x = np.asarray(x, np.float64)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def _check_live_exact(eng, live, q, k):
    """Engine results == fp64 brute force over exactly the live rows.

    Tie-aware: similarities must match the sorted brute values, and every
    returned id must be live with a true similarity equal to the reported
    one (so any permutation of exact ties passes, but a tombstoned or
    hallucinated id never does)."""
    sims, ids, _ = eng.search(jnp.asarray(q), k)
    sims = np.asarray(sims, np.float64)
    ids = np.asarray(ids)
    live_ids = np.array(sorted(live))
    rows = _norm64(np.stack([live[i] for i in live_ids]))
    qn = _norm64(q)
    s = qn @ rows.T                                     # [m, n_live]
    kk = min(k, len(live_ids))
    want = -np.sort(-s, axis=1)[:, :kk]
    np.testing.assert_allclose(sims[:, :kk], want, atol=ATOL)
    assert (ids[:, kk:] == -1).all(), "past-the-corpus slots must pad -1"
    pos_of = {int(i): p for p, i in enumerate(live_ids)}
    for r in range(q.shape[0]):
        for c in range(kk):
            i = int(ids[r, c])
            assert i in pos_of, f"returned id {i} is not live"
            true = s[r, pos_of[i]]
            assert abs(true - sims[r, c]) < ATOL, (i, true, sims[r, c])


def _build(rows, backend, **kw):
    kw.setdefault("block_size", 32)
    kw.setdefault("n_pivots", 4)
    return SearchEngine.build(rows, backend=backend, **kw)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(BACKENDS), st.integers(0, 10_000))
def test_interleaved_mutations_stay_exact(backend, seed):
    rng = np.random.default_rng(seed)
    n, d, k = 220, 12, 6
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, backend)
    h = eng.online(auto_reoptimize=False)
    live = {i: rows[i] for i in range(n)}
    q = rng.normal(size=(5, d)).astype(np.float32)
    _check_live_exact(eng, live, q, k)    # warm call (tree builds here)
    for _ in range(4):
        op = int(rng.integers(0, 3))
        if op == 0 or len(live) < k + 8:
            new = rng.normal(size=(int(rng.integers(1, 9)), d)).astype(
                np.float32)
            for i, r in zip(h.insert(new), new):
                live[i] = r
        elif op == 1:
            dead = rng.choice(sorted(live), size=5, replace=False)
            h.delete([int(x) for x in dead])
            for x in dead:
                del live[int(x)]
        else:
            h.reoptimize()
        _check_live_exact(eng, live, q, k)
    assert h.generation == 4


@pytest.mark.parametrize("backend", BACKENDS)
def test_tail_full_to_new_block_transition(backend, rng):
    """n a multiple of block_size -> zero free padded slots: the very
    first insert must append a fresh block (shape change, epoch bump) and
    stay exact; filling that block's tail exactly and inserting once more
    crosses the boundary again."""
    n, d, bs = 128, 8, 32
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, backend, block_size=bs)
    h = eng.online(auto_reoptimize=False)
    live = {i: rows[i] for i in range(n)}
    q = rng.normal(size=(3, d)).astype(np.float32)
    _check_live_exact(eng, live, q, 4)
    assert not h._free, "a full index must have no free slots"

    epoch0 = eng.index_epoch
    one = rng.normal(size=(1, d)).astype(np.float32)
    live[h.insert(one)[0]] = one[0]
    assert eng.index_epoch == epoch0 + 1          # grew by one block
    assert eng.n_slots == (n // bs + 1) * bs
    _check_live_exact(eng, live, q, 4)

    tail = rng.normal(size=(bs - 1, d)).astype(np.float32)
    for i, r in zip(h.insert(tail), tail):        # fills the block exactly
        live[i] = r
    assert eng.index_epoch == epoch0 + 1          # shape-stable fills
    over = rng.normal(size=(2, d)).astype(np.float32)
    for i, r in zip(h.insert(over), over):        # crosses into block n+2
        live[i] = r
    assert eng.index_epoch == epoch0 + 2
    _check_live_exact(eng, live, q, 4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_search_after_delete_of_former_topk_member(backend, rng):
    """Tombstone a row that was just returned as the top-1 neighbor: it
    must vanish from the next result set immediately (no rebuild), with
    the runner-up promoted — on every backend."""
    n, d = 160, 8
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, backend)
    h = eng.online(auto_reoptimize=False)
    live = {i: rows[i] for i in range(n)}
    q = rows[17][None] + np.float32(0.01) * rng.normal(size=(1, d)).astype(
        np.float32)
    sims, ids, _ = eng.search(jnp.asarray(q), 3)
    top1 = int(np.asarray(ids)[0, 0])
    assert top1 == 17
    h.delete([top1])
    del live[top1]
    sims2, ids2, _ = eng.search(jnp.asarray(q), 3)
    assert top1 not in np.asarray(ids2)
    _check_live_exact(eng, live, q, 3)


def test_reoptimize_preserves_ids_and_repacks(rng):
    n, d, bs = 96, 8, 32
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, "scan", block_size=bs)
    h = eng.online(auto_reoptimize=False)
    live = {i: rows[i] for i in range(n)}
    extra = rng.normal(size=(80, d)).astype(np.float32)
    for i, r in zip(h.insert(extra), extra):
        live[i] = r
    dead = list(range(0, n, 2))
    h.delete(dead)
    for x in dead:
        del live[x]
    slots_before = eng.n_slots
    assert h.decay_estimate > 0.5
    h.reoptimize()
    assert h.decay_estimate == 0.0
    assert eng.n_slots <= slots_before            # tombstones reclaimed
    assert h.n_live == len(live)
    q = rng.normal(size=(4, d)).astype(np.float32)
    _check_live_exact(eng, live, q, 5)            # same external ids
    # ids minted after a reoptimize continue the sequence, no reuse
    new = rng.normal(size=(1, d)).astype(np.float32)
    (nid,) = h.insert(new)
    assert nid == n + 80
    live[nid] = new[0]
    _check_live_exact(eng, live, q, 5)


def test_auto_reoptimize_triggers_at_threshold(rng):
    n, d = 64, 8
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, "scan")
    h = eng.online(reoptimize_threshold=0.25)
    epoch0 = eng.index_epoch
    h.insert(rng.normal(size=(n // 4 + 1, d)).astype(np.float32))
    assert h.decay_estimate == 0.0                # rebuild already ran
    assert eng.index_epoch > epoch0
    assert eng.n_valid == n + n // 4 + 1


def test_delete_unknown_id_raises_before_any_change(rng):
    n, d = 64, 8
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, "scan")
    h = eng.online()
    with pytest.raises(KeyError, match="not in the live set"):
        h.delete([3, 99999])
    assert 3 in h and h.n_live == n               # nothing was applied
    with pytest.raises(KeyError, match="duplicate"):
        h.delete([5, 5])
    assert 5 in h


def test_online_handle_is_singleton(rng):
    rows = rng.normal(size=(64, 8)).astype(np.float32)
    eng = _build(rows, "scan")
    h = eng.online(auto_reoptimize=False)
    assert eng.online() is h
    with pytest.raises(ValueError, match="first call"):
        eng.online(auto_reoptimize=True)


def test_appended_block_records_exact_interval(rng):
    """Satellite regression (PR 9): appended blocks used to seed
    ``dp_min = dp_max = 0`` and the insert's scatter-min/max anchored the
    interval at zero forever.  With the empty-interval sentinel the first
    rows record their EXACT per-pivot min/max."""
    n, d, bs = 64, 8, 32
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, "scan", block_size=bs)
    h = eng.online(auto_reoptimize=False)
    live = {i: rows[i] for i in range(n)}
    assert not h._free, "a full index must have no free slots"

    # rows clustered on pivot 0: their similarity to it is ~1, so the
    # appended block's true dp_min for that pivot is strictly positive —
    # the zero anchor of the pre-fix code is unambiguously wrong here
    piv0 = np.asarray(eng.index.pivots)[0]
    part = (piv0[None] + 0.01 * rng.normal(size=(3, d))).astype(np.float32)
    for i, r in zip(h.insert(part), part):
        live[i] = r
    idx = eng.index
    dp_tail = np.asarray(idx.dp)[n:n + 3]         # the 3 inserted rows
    np.testing.assert_array_equal(np.asarray(idx.dp_min)[-1],
                                  dp_tail.min(axis=0))
    np.testing.assert_array_equal(np.asarray(idx.dp_max)[-1],
                                  dp_tail.max(axis=0))
    assert np.asarray(idx.dp_min)[-1, 0] > 0.5    # the anchor bug's tell

    # fill the block exactly; the interval must stay the exact min/max
    fill = (piv0[None] + 0.01 * rng.normal(size=(bs - 3, d))).astype(
        np.float32)
    for i, r in zip(h.insert(fill), fill):
        live[i] = r
    idx = eng.index
    dp_tail = np.asarray(idx.dp)[n:n + bs]
    np.testing.assert_array_equal(np.asarray(idx.dp_min)[-1],
                                  dp_tail.min(axis=0))
    np.testing.assert_array_equal(np.asarray(idx.dp_max)[-1],
                                  dp_tail.max(axis=0))
    q = rng.normal(size=(3, d)).astype(np.float32)
    _check_live_exact(eng, live, q, 5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_delete_all_reoptimize_insert_round_trip(backend, rng):
    """Satellite regression (PR 9): an empty-live-set ``reoptimize()``
    returned before ``_apply_mutation``, so the engine kept its stale
    widened tree / dispatch caches and ``index_epoch`` never bumped.  The
    rebuild path is now uniform; the round trip must stay exact on every
    backend."""
    n, d, k = 96, 8, 4
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, backend)
    h = eng.online(auto_reoptimize=False)
    q = rng.normal(size=(3, d)).astype(np.float32)
    _check_live_exact(eng, {i: rows[i] for i in range(n)}, q, k)  # warm
    h.delete(list(range(n)))
    epoch0 = eng.index_epoch
    h.reoptimize()
    assert eng.index_epoch == epoch0 + 1, \
        "empty reoptimize must bump the epoch like every other rebuild"
    assert eng._tree_index is None and not eng._fn_cache
    assert h.n_live == 0 and h.decay_estimate == 0.0
    sims, ids, _ = eng.search(jnp.asarray(q), k)
    assert (np.asarray(ids) == -1).all()
    assert np.all(np.asarray(sims) == -np.inf)
    new = rng.normal(size=(10, d)).astype(np.float32)
    live = {i: r for i, r in zip(h.insert(new), new)}
    _check_live_exact(eng, live, q, k)


def test_sharded_interleaved_mutations_stay_exact():
    """The tentpole, single-process: 8 virtual devices, random
    insert/delete/reoptimize interleavings on ``sharded`` and
    ``sharded_tree`` engines stay tie-aware brute-equal on the live
    corpus, and the id → (shard, slot) mirror matches the device
    ``row_ids`` across reoptimize."""
    from tests.test_distributed import _run
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.search import SearchEngine
        from repro.core.distributed import replicated_row_ids

        ATOL = 3e-5

        def norm64(x):
            x = np.asarray(x, np.float64)
            return x / np.linalg.norm(x, axis=-1, keepdims=True)

        def check(eng, live, q, k):
            sims, ids, st = eng.search(jnp.asarray(q), k)
            sims = np.asarray(sims, np.float64)
            ids = np.asarray(ids)
            live_ids = np.array(sorted(live))
            s = norm64(q) @ norm64(np.stack([live[i] for i in live_ids])).T
            kk = min(k, len(live_ids))
            want = -np.sort(-s, axis=1)[:, :kk]
            np.testing.assert_allclose(sims[:, :kk], want, atol=ATOL)
            assert (ids[:, kk:] == -1).all()
            pos = {int(i): p for p, i in enumerate(live_ids)}
            for r in range(q.shape[0]):
                for c in range(kk):
                    i = int(ids[r, c])
                    assert i in pos, f"returned id {i} is not live"
                    assert abs(s[r, pos[i]] - sims[r, c]) < ATOL
            return st

        mesh = jax.make_mesh((8,), ("data",))
        for tree_shards in (False, True):
            for seed in (0, 1):
                rng = np.random.default_rng(seed)
                n, d, k = 603, 16, 7
                rows = rng.normal(size=(n, d)).astype(np.float32)
                eng = SearchEngine.build(rows, mesh=mesh, n_pivots=4,
                                         block_size=16,
                                         tree_shards=tree_shards)
                assert eng.backend_name == "sharded"
                h = eng.online(auto_reoptimize=False)
                live = {i: rows[i] for i in range(n)}
                q = rng.normal(size=(4, d)).astype(np.float32)
                check(eng, live, q, k)          # warm: compile the closure
                for _ in range(5):
                    op = int(rng.integers(0, 3))
                    if op == 0 or len(live) < k + 16:
                        m = int(rng.integers(1, 12))
                        new = rng.normal(size=(m, d)).astype(np.float32)
                        for i, r in zip(h.insert(new), new):
                            live[i] = r
                    elif op == 1:
                        dead = rng.choice(sorted(live), size=7,
                                          replace=False)
                        h.delete([int(x) for x in dead])
                        for x in dead:
                            del live[int(x)]
                    else:
                        h.reoptimize()
                        rid = replicated_row_ids(eng.index, mesh)
                        want = {int(r): (s2, sl)
                                for s2 in range(rid.shape[0])
                                for sl, r in enumerate(rid[s2]) if r >= 0}
                        assert want == h._id_pos, "id map drifted"
                    check(eng, live, q, k)
                assert h.generation == 5
        print("OK")
    """)


def test_sharded_shape_stable_mutations_run_at_zero_retraces():
    """Shape-stable sharded mutations must keep the cached sharded
    executables (index flows as an argument): the search right after a
    tail insert or a tombstone delete reports ``retraces == 0``, on both
    the flat per-shard scan and the per-shard tree descent.  Growing a
    block bumps the epoch instead."""
    from tests.test_distributed import _run
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.search import SearchEngine
        rng = np.random.default_rng(7)
        n, d, k = 500, 16, 6
        rows = rng.normal(size=(n, d)).astype(np.float32)
        mesh = jax.make_mesh((8,), ("data",))
        for tree_shards in (False, True):
            eng = SearchEngine.build(rows, mesh=mesh, n_pivots=4,
                                     block_size=16,
                                     tree_shards=tree_shards)
            h = eng.online(auto_reoptimize=False)
            q = rng.normal(size=(3, d)).astype(np.float32)
            eng.search(q, k)                       # compile
            _, _, st = eng.search(q, k)
            assert st.retraces == 0
            epoch0 = eng.index_epoch
            ids = h.insert(rng.normal(size=(4, d)).astype(np.float32))
            assert eng.index_epoch == epoch0       # free tail slots exist
            _, _, st = eng.search(q, k)
            assert st.retraces == 0, (tree_shards, "insert", st.retraces)
            h.delete(ids[:2])
            _, _, st = eng.search(q, k)
            assert st.retraces == 0, (tree_shards, "delete", st.retraces)
            # exhaust every free slot -> the next insert appends one block
            # on every shard and must bump the epoch (one retrace after)
            free = sum(len(f) for f in h._free)
            h.insert(rng.normal(size=(free + 1, d)).astype(np.float32))
            assert eng.index_epoch == epoch0 + 1
            _, _, st = eng.search(q, k)
            assert st.retraces >= 1
            _, _, st = eng.search(q, k)
            assert st.retraces == 0
        print("OK")
    """)


def test_sharded_tree_online_prunes_at_least_flat():
    """Per-shard descent pruning stays a superset of the flat per-shard
    pruning after mutations: apply the SAME mutation sequence to a flat
    sharded engine and a tree_shards one; the tree engine's block-prune
    fraction must be >= the flat engine's on every following search."""
    from tests.test_distributed import _run
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.search import SearchEngine
        rng = np.random.default_rng(3)
        n, d, k = 640, 16, 5
        centers = rng.normal(size=(8, d))
        rows = (centers[rng.integers(0, 8, n)]
                + 0.05 * rng.normal(size=(n, d))).astype(np.float32)
        mesh = jax.make_mesh((8,), ("data",))
        engs = [SearchEngine.build(rows, mesh=mesh, n_pivots=4,
                                   block_size=16, tree_shards=ts)
                for ts in (False, True)]
        hs = [e.online(auto_reoptimize=False) for e in engs]
        q = (centers[rng.integers(0, 8, 4)]
             + 0.05 * rng.normal(size=(4, d))).astype(np.float32)
        new = (centers[rng.integers(0, 8, 20)]
               + 0.05 * rng.normal(size=(20, d))).astype(np.float32)
        dead = list(range(0, 40, 2))
        for e, h in zip(engs, hs):
            e.search(q, k)
            ids = h.insert(new)
            h.delete(dead)
            assert h._id_pos == hs[0]._id_pos      # identical placement
        stats = [e.search(q, k)[2] for e in engs]
        flat_blk = float(stats[0].block_prune_frac)
        tree_blk = float(stats[1].block_prune_frac)
        assert stats[1].tree_prune_frac is not None
        assert tree_blk >= flat_blk - 1e-6, (tree_blk, flat_blk)
        print("OK")
    """)
