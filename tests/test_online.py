"""Online mutation (DESIGN.md §3.9): random interleavings of
insert/delete/search stay tie-aware brute-equal on the *live* corpus for
every backend, through the block-tail-full -> new-block transition and
across full reoptimizes.  The correctness argument under test is
conservative widening: inserts only loosen intervals (bounds stay true
upper bounds), tombstones mask per row before top-k."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.search import SearchEngine

BACKENDS = ["scan", "brute", "tree", "kernel"]
ATOL = 3e-5


def _norm64(x):
    x = np.asarray(x, np.float64)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def _check_live_exact(eng, live, q, k):
    """Engine results == fp64 brute force over exactly the live rows.

    Tie-aware: similarities must match the sorted brute values, and every
    returned id must be live with a true similarity equal to the reported
    one (so any permutation of exact ties passes, but a tombstoned or
    hallucinated id never does)."""
    sims, ids, _ = eng.search(jnp.asarray(q), k)
    sims = np.asarray(sims, np.float64)
    ids = np.asarray(ids)
    live_ids = np.array(sorted(live))
    rows = _norm64(np.stack([live[i] for i in live_ids]))
    qn = _norm64(q)
    s = qn @ rows.T                                     # [m, n_live]
    kk = min(k, len(live_ids))
    want = -np.sort(-s, axis=1)[:, :kk]
    np.testing.assert_allclose(sims[:, :kk], want, atol=ATOL)
    assert (ids[:, kk:] == -1).all(), "past-the-corpus slots must pad -1"
    pos_of = {int(i): p for p, i in enumerate(live_ids)}
    for r in range(q.shape[0]):
        for c in range(kk):
            i = int(ids[r, c])
            assert i in pos_of, f"returned id {i} is not live"
            true = s[r, pos_of[i]]
            assert abs(true - sims[r, c]) < ATOL, (i, true, sims[r, c])


def _build(rows, backend, **kw):
    kw.setdefault("block_size", 32)
    kw.setdefault("n_pivots", 4)
    return SearchEngine.build(rows, backend=backend, **kw)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(BACKENDS), st.integers(0, 10_000))
def test_interleaved_mutations_stay_exact(backend, seed):
    rng = np.random.default_rng(seed)
    n, d, k = 220, 12, 6
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, backend)
    h = eng.online(auto_reoptimize=False)
    live = {i: rows[i] for i in range(n)}
    q = rng.normal(size=(5, d)).astype(np.float32)
    _check_live_exact(eng, live, q, k)    # warm call (tree builds here)
    for _ in range(4):
        op = int(rng.integers(0, 3))
        if op == 0 or len(live) < k + 8:
            new = rng.normal(size=(int(rng.integers(1, 9)), d)).astype(
                np.float32)
            for i, r in zip(h.insert(new), new):
                live[i] = r
        elif op == 1:
            dead = rng.choice(sorted(live), size=5, replace=False)
            h.delete([int(x) for x in dead])
            for x in dead:
                del live[int(x)]
        else:
            h.reoptimize()
        _check_live_exact(eng, live, q, k)
    assert h.generation == 4


@pytest.mark.parametrize("backend", BACKENDS)
def test_tail_full_to_new_block_transition(backend, rng):
    """n a multiple of block_size -> zero free padded slots: the very
    first insert must append a fresh block (shape change, epoch bump) and
    stay exact; filling that block's tail exactly and inserting once more
    crosses the boundary again."""
    n, d, bs = 128, 8, 32
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, backend, block_size=bs)
    h = eng.online(auto_reoptimize=False)
    live = {i: rows[i] for i in range(n)}
    q = rng.normal(size=(3, d)).astype(np.float32)
    _check_live_exact(eng, live, q, 4)
    assert not h._free, "a full index must have no free slots"

    epoch0 = eng.index_epoch
    one = rng.normal(size=(1, d)).astype(np.float32)
    live[h.insert(one)[0]] = one[0]
    assert eng.index_epoch == epoch0 + 1          # grew by one block
    assert eng.n_slots == (n // bs + 1) * bs
    _check_live_exact(eng, live, q, 4)

    tail = rng.normal(size=(bs - 1, d)).astype(np.float32)
    for i, r in zip(h.insert(tail), tail):        # fills the block exactly
        live[i] = r
    assert eng.index_epoch == epoch0 + 1          # shape-stable fills
    over = rng.normal(size=(2, d)).astype(np.float32)
    for i, r in zip(h.insert(over), over):        # crosses into block n+2
        live[i] = r
    assert eng.index_epoch == epoch0 + 2
    _check_live_exact(eng, live, q, 4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_search_after_delete_of_former_topk_member(backend, rng):
    """Tombstone a row that was just returned as the top-1 neighbor: it
    must vanish from the next result set immediately (no rebuild), with
    the runner-up promoted — on every backend."""
    n, d = 160, 8
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, backend)
    h = eng.online(auto_reoptimize=False)
    live = {i: rows[i] for i in range(n)}
    q = rows[17][None] + np.float32(0.01) * rng.normal(size=(1, d)).astype(
        np.float32)
    sims, ids, _ = eng.search(jnp.asarray(q), 3)
    top1 = int(np.asarray(ids)[0, 0])
    assert top1 == 17
    h.delete([top1])
    del live[top1]
    sims2, ids2, _ = eng.search(jnp.asarray(q), 3)
    assert top1 not in np.asarray(ids2)
    _check_live_exact(eng, live, q, 3)


def test_reoptimize_preserves_ids_and_repacks(rng):
    n, d, bs = 96, 8, 32
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, "scan", block_size=bs)
    h = eng.online(auto_reoptimize=False)
    live = {i: rows[i] for i in range(n)}
    extra = rng.normal(size=(80, d)).astype(np.float32)
    for i, r in zip(h.insert(extra), extra):
        live[i] = r
    dead = list(range(0, n, 2))
    h.delete(dead)
    for x in dead:
        del live[x]
    slots_before = eng.n_slots
    assert h.decay_estimate > 0.5
    h.reoptimize()
    assert h.decay_estimate == 0.0
    assert eng.n_slots <= slots_before            # tombstones reclaimed
    assert h.n_live == len(live)
    q = rng.normal(size=(4, d)).astype(np.float32)
    _check_live_exact(eng, live, q, 5)            # same external ids
    # ids minted after a reoptimize continue the sequence, no reuse
    new = rng.normal(size=(1, d)).astype(np.float32)
    (nid,) = h.insert(new)
    assert nid == n + 80
    live[nid] = new[0]
    _check_live_exact(eng, live, q, 5)


def test_auto_reoptimize_triggers_at_threshold(rng):
    n, d = 64, 8
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, "scan")
    h = eng.online(reoptimize_threshold=0.25)
    epoch0 = eng.index_epoch
    h.insert(rng.normal(size=(n // 4 + 1, d)).astype(np.float32))
    assert h.decay_estimate == 0.0                # rebuild already ran
    assert eng.index_epoch > epoch0
    assert eng.n_valid == n + n // 4 + 1


def test_delete_unknown_id_raises_before_any_change(rng):
    n, d = 64, 8
    rows = rng.normal(size=(n, d)).astype(np.float32)
    eng = _build(rows, "scan")
    h = eng.online()
    with pytest.raises(KeyError, match="not in the live set"):
        h.delete([3, 99999])
    assert 3 in h and h.n_live == n               # nothing was applied
    with pytest.raises(KeyError, match="duplicate"):
        h.delete([5, 5])
    assert 5 in h


def test_online_handle_is_singleton(rng):
    rows = rng.normal(size=(64, 8)).astype(np.float32)
    eng = _build(rows, "scan")
    h = eng.online(auto_reoptimize=False)
    assert eng.online() is h
    with pytest.raises(ValueError, match="first call"):
        eng.online(auto_reoptimize=True)


def test_sharded_engine_refuses_mutation():
    """The dist path has no insert placement protocol: ``.online()`` must
    be an explicit NotImplementedError, not a silent local-shard write."""
    from tests.test_distributed import _run
    _run("""
        import numpy as np, jax
        from repro.search import SearchEngine
        db = np.random.default_rng(0).normal(size=(512, 16)).astype("float32")
        mesh = jax.make_mesh((8,), ("data",))
        eng = SearchEngine.build(db, n_pivots=4, block_size=32, mesh=mesh)
        assert eng.backend_name == "sharded"
        try:
            eng.online()
        except NotImplementedError as e:
            assert "sharded" in str(e)
        else:
            raise AssertionError("sharded engine accepted online()")
        print("OK")
    """)
