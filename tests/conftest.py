import os
import sys

# tests must see exactly ONE device (the dry-run sets its own flags in a
# separate process); keep any user XLA_FLAGS out of the way.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401  (real install preferred)
except ModuleNotFoundError:
    from tests._hypothesis_fallback import install as _install_hyp_fallback
    _install_hyp_fallback()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def clustered(rng, n, d, n_centers=6, noise=0.07):
    """Unit vectors in a few angular clusters (the regime where the paper's
    bounds have pruning power; uniform high-dim data concentrates)."""
    c = rng.normal(size=(n_centers, d))
    c /= np.linalg.norm(c, axis=1, keepdims=True)
    x = c[rng.integers(0, n_centers, n)] + noise * rng.normal(size=(n, d))
    return (x / np.linalg.norm(x, axis=1, keepdims=True)).astype(np.float32)
