"""Engine dispatch cache: the hot path must not retrace.

ISSUE 6 tentpole (b): ``SearchEngine.search`` caches one fused jitted
callee per ``(backend, k, query shape, knob tuple)``, so a warm repeated
call costs a single dispatch of an already-compiled executable.  The
cache is observable through ``SearchStats.retraces`` — a host-side
counter bumped by a trace-time side effect inside every fused body, so it
counts *traces*, not calls.  These tests pin the cache contract:

* a second identical call reports ``retraces == 0``;
* changing ``k`` or the batch shape misses the cache exactly once, and
  switching back to an earlier signature hits again (entries are
  retained, not evicted);
* the scan backend's donated best-first scratch buffer cycles without
  corrupting results across repeated calls;
* tracer queries (an outer ``jax.jit``, the serve path) still work —
  donation is disabled there, results stay exact.
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

from repro.core import ref
from repro.search import SearchEngine

N, D, K = 512, 16, 8


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(7)
    c = ref.normalize(rng.normal(size=(4, D)))
    return ref.normalize(c[rng.integers(0, 4, N)] +
                         0.1 * rng.normal(size=(N, D))).astype(np.float32)


@pytest.fixture(scope="module")
def queries(db):
    rng = np.random.default_rng(8)
    q = db[rng.choice(N, 16, replace=False)]
    return ref.normalize(q + 0.01 * rng.normal(size=q.shape)).astype(
        np.float32)


def _engine(db, backend, **kw):
    return SearchEngine.build(db, n_pivots=4, block_size=64,
                              backend=backend, **kw)


@pytest.mark.parametrize("backend", ["brute", "scan", "tree", "kernel"])
def test_warm_call_does_not_retrace(db, queries, backend):
    eng = _engine(db, backend)
    _, _, first = eng.search(queries, K)
    sims, ids, warm = eng.search(queries, K)
    assert first.retraces >= 1            # the cold call paid the trace
    assert warm.retraces == 0             # ...exactly once
    sref, _ = ref.brute_force_knn(queries, db, K)
    np.testing.assert_allclose(np.asarray(sims), sref, atol=3e-5)


@pytest.mark.parametrize("backend", ["brute", "scan", "tree"])
def test_k_and_shape_changes_miss_exactly_once(db, queries, backend):
    eng = _engine(db, backend)
    _, _, cold = eng.search(queries, K)
    per_trace = cold.retraces             # fused = 1 trace per signature
    assert per_trace >= 1

    _, _, st_k = eng.search(queries, K + 3)
    assert st_k.retraces == per_trace     # new k -> one new callee

    _, _, st_m = eng.search(queries[:5], K)
    assert st_m.retraces == per_trace     # new batch shape -> one more

    for q, k in ((queries, K), (queries, K + 3), (queries[:5], K)):
        _, _, st = eng.search(q, k)
        assert st.retraces == 0           # all three signatures retained


def test_best_first_donated_scratch_stays_exact(db, queries):
    eng = _engine(db, "scan", best_first=True)
    sref, _ = ref.brute_force_knn(queries, db, K)
    for _ in range(4):                    # scratch donates + cycles each call
        sims, _, st = eng.search(queries, K)
        np.testing.assert_allclose(np.asarray(sims), sref, atol=3e-5)
    assert st.retraces == 0


def test_tracer_queries_skip_donation_and_stay_exact(db, queries):
    eng = _engine(db, "scan", best_first=True)

    @jax.jit
    def serve(q):
        # deliberate: this test exists to prove in-jit engine calls work
        sims, ids, _ = eng.search(q, K)  # repro-lint: disable=R008
        return sims, ids

    sref, _ = ref.brute_force_knn(queries, db, K)
    for _ in range(2):
        sims, _ = serve(queries)
        np.testing.assert_allclose(np.asarray(sims), sref, atol=3e-5)


def test_unfusable_path_reports_unknown_retraces(db, queries):
    # tree + kernel leaves + pruning is the one legacy multi-dispatch
    # configuration left: retraces must be None (uncountable), never a
    # wrong number
    eng = _engine(db, "tree", leaf_eval="kernel")
    sims, _, st = eng.search(queries, K)
    assert st.retraces is None
    sref, _ = ref.brute_force_knn(queries, db, K)
    np.testing.assert_allclose(np.asarray(sims), sref, atol=3e-5)


def test_stats_dict_roundtrips_retraces(db, queries):
    eng = _engine(db, "scan")
    _, _, st = eng.search(queries, K)
    assert st.as_dict()["retraces"] == st.retraces


@pytest.mark.parametrize("backend", ["scan", "kernel", "tree"])
def test_n_pivots_joins_cache_signature(db, queries, backend):
    """ISSUE 7: the joint-bound depth is part of the fused-dispatch cache
    key — warm repeats at n_pivots > 0 stay retrace-free, changing the
    knob misses exactly once, and switching back hits the retained
    entry.  Exactness holds at every depth."""
    eng = _engine(db, backend, bound_pivots=2)
    assert eng.n_pivots == 2
    _, _, cold = eng.search(queries, K)
    per_trace = cold.retraces
    assert per_trace >= 1
    sims, _, warm = eng.search(queries, K)
    assert warm.retraces == 0
    sref, _ = ref.brute_force_knn(queries, db, K)
    np.testing.assert_allclose(np.asarray(sims), sref, atol=3e-5)

    eng.n_pivots = 4                      # knob change -> one new callee
    sims, _, st = eng.search(queries, K)
    assert st.retraces == per_trace
    assert st.n_pivots == 4
    np.testing.assert_allclose(np.asarray(sims), sref, atol=3e-5)
    _, _, st2 = eng.search(queries, K)
    assert st2.retraces == 0

    eng.n_pivots = 2                      # first entry retained, not evicted
    _, _, st3 = eng.search(queries, K)
    assert st3.retraces == 0


@pytest.mark.parametrize("backend", ["scan", "tree", "kernel"])
def test_online_mutation_cache_contract(db, queries, backend):
    """ISSUE 8: shape-stable mutations (tail insert, tombstone delete)
    keep every cached executable — the next search reports 0 retraces —
    while a shape-changing mutation (appended blocks) bumps
    ``index_epoch`` so the old entries (and their stale donated scratch
    shapes) can never serve the grown index: exactly one retrace, then
    warm again."""
    eng = _engine(db[:500], backend)      # 12 free slots in the padded tail
    h = eng.online(auto_reoptimize=False)
    _, _, cold = eng.search(queries, K)
    per_trace = cold.retraces
    assert per_trace >= 1

    epoch0 = eng.index_epoch
    ids = h.insert(db[:3])                # fits in the padded tail
    h.delete(ids[:1])
    assert eng.index_epoch == epoch0      # shape-stable: same epoch
    sims, _, st = eng.search(queries, K)
    assert st.retraces == 0               # cache hit through the mutation
    assert st.generation == 2

    h.insert(np.tile(db, (2, 1)))         # overflows free slots -> grow
    assert eng.index_epoch > epoch0
    _, _, grown = eng.search(queries, K)
    assert grown.retraces == per_trace    # exactly one new trace
    _, _, warm = eng.search(queries, K)
    assert warm.retraces == 0


def test_brute_backend_reports_no_pivot_depth(db, queries):
    # brute consumes no bounds: the stats field is None, not a number that
    # suggests the cap was evaluated
    eng = _engine(db, "brute", bound_pivots=4)
    _, _, st = eng.search(queries, K)
    assert st.n_pivots is None
