"""Exactness of the block-pruned index vs fp64 brute force (+ properties)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ref
from repro.core.index import build_index, reorder_perm, search_brute
from repro.core.vptree import VPTree
from repro.search import SearchEngine
from tests.conftest import clustered


def _check_exact(db, q, k, **kw):
    idx = build_index(jnp.asarray(db), **kw)
    eng = SearchEngine(idx, backend="scan")
    s, i, stats = eng.search(jnp.asarray(q), k)
    sref, iref = ref.brute_force_knn(q, db, k)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)
    # indices may permute on exact ties; compare as sets per row
    got = np.sort(np.asarray(i), axis=1)
    want = np.sort(iref, axis=1)
    mismatch = (got != want).mean()
    assert mismatch < 0.02, f"id mismatch {mismatch}"  # ties only
    return stats


def test_exact_uniform(rng):
    db = rng.normal(size=(1500, 24)).astype(np.float32)
    q = rng.normal(size=(13, 24)).astype(np.float32)
    _check_exact(db, q, 10, n_pivots=8, block_size=64)


def test_exact_clustered_with_pruning(rng):
    db = clustered(rng, 4000, 32)
    q = db[::500] + 0.01 * rng.normal(size=(8, 32)).astype(np.float32)
    stats = _check_exact(db, q, 5, n_pivots=16, block_size=64)
    assert float(stats["block_prune_frac"]) > 0.2, "reordered blocks should prune"


def test_exact_no_reorder(rng):
    db = clustered(rng, 2000, 16)
    q = db[:4]
    _check_exact(db, q, 3, n_pivots=8, block_size=128, reorder=False)


def test_padding_and_small_db(rng):
    db = rng.normal(size=(97, 8)).astype(np.float32)   # < block, odd size
    q = rng.normal(size=(3, 8)).astype(np.float32)
    _check_exact(db, q, 5, n_pivots=4, block_size=64)


def test_k_equals_n(rng):
    db = rng.normal(size=(40, 8)).astype(np.float32)
    q = rng.normal(size=(2, 8)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=16)
    s, i, _ = SearchEngine(idx, backend="scan").search(jnp.asarray(q), 40)
    sref, iref = ref.brute_force_knn(q, db, 40)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)


def test_brute_path_matches(rng):
    db = rng.normal(size=(300, 12)).astype(np.float32)
    q = rng.normal(size=(5, 12)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=64)
    s1, i1, _ = SearchEngine(idx, backend="scan").search(
        jnp.asarray(q), 7, prune=False)
    s2, i2 = search_brute(idx, jnp.asarray(q), 7)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 400), st.integers(2, 24), st.integers(1, 8),
       st.integers(0, 1000))
def test_exactness_property(n, d, k, seed):
    rng = np.random.default_rng(seed)
    db = rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(4, d)).astype(np.float32)
    k = min(k, n)
    idx = build_index(jnp.asarray(db), n_pivots=min(4, n), block_size=32)
    s, i, _ = SearchEngine(idx, backend="scan").search(jnp.asarray(q), k)
    sref, _ = ref.brute_force_knn(q, db, k)
    np.testing.assert_allclose(np.asarray(s), sref, atol=5e-5)


def test_reorder_perm_integer_safe_at_64_pivots():
    """Regression: the old float sort key (``nearest * 4.0 - near_sim``)
    burned ~8 mantissa bits on the group id at n_pivots=64, collapsing
    within-group similarities closer than ~3e-5.  The lexicographic key
    must match a numpy lexsort oracle exactly."""
    n_pivots = 64
    n = 512
    rng = np.random.default_rng(3)
    nearest = rng.integers(0, n_pivots, n)
    # sims packed tightly (1e-6 apart) near 1.0: representable in fp32 on
    # their own, NOT representable once shifted by the group term ~256
    near_sim = 0.999 + 1e-6 * rng.integers(0, 200, n)
    dp = np.full((n, n_pivots), -1.0, np.float32)
    dp[np.arange(n), nearest] = near_sim.astype(np.float32)
    valid = np.ones(n, bool)
    valid[-7:] = False                       # padding rows must sort last
    perm = np.asarray(reorder_perm(jnp.asarray(dp), jnp.asarray(valid),
                                   n_pivots))
    group = np.where(valid, nearest, n_pivots)
    want = np.lexsort((-dp[np.arange(n), nearest], group))
    np.testing.assert_array_equal(perm, want)
    g_got = group[perm]
    assert (np.diff(g_got) >= 0).all(), "groups must be contiguous, pad last"
    sims_sorted = dp[np.arange(n), nearest][perm]
    for g in range(n_pivots):
        s = sims_sorted[g_got == g]
        assert (np.diff(s) <= 0).all(), f"group {g} not descending"
    # the old float key fails this exact check:
    old_key = np.where(valid, nearest * 4.0 - dp[np.arange(n), nearest],
                       np.inf).astype(np.float32)
    old_perm = np.argsort(old_key, kind="stable")
    old_sims = dp[np.arange(n), nearest][old_perm]
    old_groups = group[old_perm]
    old_ok = all((np.diff(old_sims[old_groups == g]) <= 0).all()
                 for g in range(n_pivots))
    assert not old_ok, "float key unexpectedly survived the 64-pivot regime"


def test_build_index_64_pivots_exact(rng):
    """End-to-end at n_pivots=64: reorder keeps search exact."""
    db = clustered(rng, 1500, 48, n_centers=12)
    q = db[::300] + 0.01 * rng.normal(size=(5, 48)).astype(np.float32)
    _check_exact(db, q, 8, n_pivots=64, block_size=64)


def test_search_shim_removed(rng):
    """The pre-engine entry point no longer executes at all: after one
    release as a DeprecationWarning shim it is a hard TypeError carrying
    the SearchEngine migration hint (docs/search-api.md)."""
    from repro.core.index import search
    db = rng.normal(size=(120, 8)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=32)
    with pytest.raises(TypeError, match="SearchEngine"):
        search(idx, jnp.asarray(db[:2]), 3)
    with pytest.raises(TypeError, match="docs/search-api.md"):
        search(idx, jnp.asarray(db[:2]), 3, warm_start=True)


def test_scalar_reference_pruned_knn(rng):
    """The paper-style scalar LAESA reference is exact and prunes."""
    db = clustered(rng, 800, 16)
    q = db[:5]
    piv = db[rng.choice(800, 8, replace=False)]
    s, i, frac = ref.pruned_knn_reference(q, db, piv, 5)
    sref, iref = ref.brute_force_knn(q, db, 5)
    np.testing.assert_allclose(s, sref, atol=1e-12)
    assert frac < 0.9, "should compute fewer than 90% of exact sims"


def test_vptree_exact_and_bounds_ranked(rng):
    db = clustered(rng, 1200, 24)
    q = db[:6] + 0.01 * rng.normal(size=(6, 24)).astype(np.float32)
    vt = VPTree(db, leaf_size=8)
    sref, iref = ref.brute_force_knn(q, db, 5)
    sm, _, fm = vt.knn_batch(q, 5, bound="mult")
    se, _, fe = vt.knn_batch(q, 5, bound="euclid")
    np.testing.assert_allclose(sm, sref, atol=1e-9)
    np.testing.assert_allclose(se, sref, atol=1e-9)
    assert fm <= fe + 0.02, "Eq. 13 (mult) should prune at least as well"
