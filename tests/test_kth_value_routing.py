"""Regression: every warm-start τ path keeps the TopkRewriter guard.

The PR 6 latency bug: ``lax.top_k(x, k)[0][:, -1]`` folds into a
``[k-1:k]`` slice, XLA's TopkRewriter no longer matches, and the line
silently lowers to a full O(n log n) sort (~10x at [64, 128]).  The
sanctioned guard is ``repro.kernels.ref.kth_value`` (barrier, then
slice); ``search/tree.py`` and ``dist/collectives.py`` carry the same
barrier inline at their tuple-unpack sites because they need the whole
[m, k] block, not just its k-th column.

repro-lint R001 catches the *syntactic* pattern; these tests pin the
*semantic* property — each warm-start path's jaxpr still contains the
``opt_barrier`` that keeps the rewrite alive, and the flat prescan
still routes through ``kth_value`` itself — so a refactor cannot drop
the guard while keeping the naive slice out of R001's sight.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.index import build_index
from repro.dist.collectives import global_tau_merge
from repro.dist.compat import shard_map
from repro.kernels import ref as kref
from repro.search import backends, build_tree
from repro.search.backends import prep_queries
from repro.search.tree import tree_warm_start

K = 8


def _jaxpr_has_barrier(fn, *args) -> bool:
    return "opt_barrier" in str(jax.make_jaxpr(fn)(*args))


def _small_tree(seed=0, n=256, d=8):
    rng = np.random.default_rng(seed)
    db = rng.normal(size=(n, d)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=32)
    return idx, build_tree(idx)


def test_kth_value_keeps_barrier():
    scores = jnp.ones((4, 64), jnp.float32)
    assert _jaxpr_has_barrier(lambda s: kref.kth_value(s, K), scores)


def test_tree_warm_start_keeps_barrier():
    idx, tree = _small_tree()
    qn, qp = prep_queries(idx, jnp.ones((3, idx.db.shape[1]), jnp.float32))
    assert _jaxpr_has_barrier(
        lambda a, b: tree_warm_start(tree, a, b, K, width=2), qn, qp)


def test_global_tau_merge_keeps_barrier():
    mesh = Mesh(np.array(jax.devices("cpu")[:1]), ("shards",))
    merged = shard_map(
        lambda s, v: global_tau_merge(s, v, K, "shards"),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_vma=False)
    sims = jnp.linspace(0.0, 1.0, 3 * K).reshape(3, K)
    valid = jnp.ones((3, K), bool)
    assert _jaxpr_has_barrier(merged, sims, valid)
    # and the merge is still exact about real-candidate counts
    tau = merged(sims, valid)
    np.testing.assert_allclose(np.asarray(tau),
                               np.asarray(jnp.sort(sims, axis=1)[:, 0]))


def test_flat_prescan_routes_through_kth_value(monkeypatch):
    idx, _ = _small_tree()
    calls = []
    real = kref.kth_value

    def counting(scores, k):
        calls.append((scores.shape, k))
        return real(scores, k)

    # backends.py does `from repro.kernels import ref as kref`: patching
    # the module attribute is seen by tau_warm_start at call time
    monkeypatch.setattr(backends.kref, "kth_value", counting)
    nb, bs = idx.n_blocks, idx.block_size
    qn, qp = prep_queries(idx, jnp.ones((3, idx.db.shape[1]), jnp.float32))
    ub = jnp.ones((3, nb), jnp.float32)
    db_blocks = idx.db.reshape(nb, bs, -1)
    valid_blocks = idx.valid.reshape(nb, bs)
    tau = backends.tau_warm_start(qn, db_blocks, valid_blocks, ub, K,
                                  n_pre=2)
    assert calls, "tau_warm_start no longer routes through kref.kth_value"
    assert tau.shape == (3,)
