"""Pivot-tree backend: structural invariants (hypothesis property tests),
transitive-bound domination, and brute-force-identical results across leaf
evaluation paths — the exactness half of DESIGN.md §3.5."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ref
from repro.core.index import build_index
from repro.search import SearchEngine, auto_backend, available_backends, build_tree
from repro.search.backends import prep_queries
from repro.search.tree import tree_descend, tree_warm_start
from tests.conftest import clustered


def _sets_equal(ids, iref):
    return (np.sort(np.asarray(ids), 1) == np.sort(iref, 1)).mean()


def _adversarial(rng, n, d):
    """Adversarially clustered: tight duplicate-heavy clusters plus a thin
    uniform background, the regime where a wrong bound or a stale τ seed
    would actually change the result set."""
    n_dup = n // 3
    base = clustered(rng, n - n_dup, d, n_centers=4, noise=0.01)
    dup = base[rng.integers(0, len(base), n_dup)] + 1e-4 * rng.normal(
        size=(n_dup, d)).astype(np.float32)
    x = np.concatenate([base, dup])
    return (x / np.linalg.norm(x, axis=1, keepdims=True)).astype(np.float32)


def test_tree_backend_registered():
    assert "tree" in available_backends()


def test_auto_selects_tree_for_deep_index(rng):
    """≥ 256 blocks on CPU: the flat per-block bound pass dominates and
    auto-selection hands the index to the transitive descent."""
    db = rng.normal(size=(256 * 32, 8)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=32)
    assert auto_backend(idx) == "tree"
    # shallow index keeps the flat scan (regression for the old rule)
    small = build_index(jnp.asarray(db[:2000]), n_pivots=4, block_size=64)
    assert auto_backend(small) == "scan"


# ---------------------------------------------------------------------------
# invariant (a): every point lands in exactly one leaf
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(10, 500), st.integers(2, 24), st.integers(0, 1000))
def test_every_point_in_exactly_one_leaf(n, d, seed):
    rng = np.random.default_rng(seed)
    db = rng.normal(size=(n, d)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=min(4, n), block_size=32)
    tree = build_tree(idx)
    nb, bs, nl = idx.n_blocks, idx.block_size, tree.n_leaf_slots
    assert nl >= nb and (nl & (nl - 1)) == 0          # power-of-two leaf row
    # leaf slot s covers block s: collect original row ids per leaf bucket
    row_ids = np.asarray(idx.row_ids).reshape(nb, bs)
    valid = np.asarray(idx.valid).reshape(nb, bs)
    seen = np.concatenate([row_ids[b][valid[b]] for b in range(nb)])
    # every original row appears exactly once across all leaf buckets
    np.testing.assert_array_equal(np.sort(seen), np.arange(n))
    # leaf slots beyond the block count are structurally invalid
    node_valid = np.asarray(tree.node_valid)
    assert not node_valid[nl + nb:].any()
    # and a leaf is valid iff its block holds at least one real row
    np.testing.assert_array_equal(node_valid[nl:nl + nb], valid.any(axis=1))


# ---------------------------------------------------------------------------
# invariant (b): node bounds dominate every descendant similarity
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(20, 400), st.integers(2, 16), st.integers(0, 1000))
def test_node_bounds_dominate_descendants(n, d, seed):
    rng = np.random.default_rng(seed)
    db = clustered(rng, n, d) if seed % 2 else \
        rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(3, d)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=min(4, n), block_size=32)
    tree = build_tree(idx)
    nb, bs, nl = idx.n_blocks, idx.block_size, tree.n_leaf_slots
    qn, qp = prep_queries(idx, jnp.asarray(q))
    # per-node Eq. 13 interval bound, same formula the descent evaluates
    from repro.kernels.ref import block_bounds
    ub = np.asarray(block_bounds(qp, tree.node_lo, tree.node_hi))  # [m, 2nl]
    node_valid = np.asarray(tree.node_valid)
    # true max similarity per leaf, then fold bottom-up: a node's true max
    # is the max over its children — exactly the subtree's best candidate
    sims = np.asarray(qn @ idx.db.T)                               # [m, n_pad]
    sims = np.where(np.asarray(idx.valid)[None, :], sims, -np.inf)
    best = np.full((sims.shape[0], 2 * nl), -np.inf)
    best[:, nl:nl + nb] = sims.reshape(-1, nb, bs).max(axis=2)
    sz = nl // 2
    while sz >= 1:
        best[:, sz:2 * sz] = best[:, 2 * sz:4 * sz].reshape(
            -1, sz, 2).max(axis=2)
        sz //= 2
    mask = node_valid[None, 1:] & np.isfinite(best[:, 1:])
    assert (ub[:, 1:][mask] + 1e-5 >= best[:, 1:][mask]).all(), (
        "an internal node's transitive Eq. 13 bound fell below a "
        "descendant's true similarity")


# ---------------------------------------------------------------------------
# invariant (c): tree top-k equals brute-force top-k
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(30, 500), st.integers(2, 24), st.integers(1, 12),
       st.integers(0, 1000))
def test_tree_topk_matches_brute_property(n, d, k, seed):
    rng = np.random.default_rng(seed)
    kind = seed % 3
    if kind == 0:
        db = rng.normal(size=(n, d)).astype(np.float32)
    elif kind == 1:
        db = clustered(rng, n, d)
    else:
        db = _adversarial(rng, n, d)
    q = rng.normal(size=(4, d)).astype(np.float32)
    k = min(k, n)
    idx = build_index(jnp.asarray(db), n_pivots=min(4, n), block_size=32)
    sref, iref = ref.brute_force_knn(q, db, k)
    eng = SearchEngine(idx, backend="tree", bm=8)
    s, i, _ = eng.search(jnp.asarray(q), k)
    np.testing.assert_allclose(np.asarray(s), sref, atol=5e-5,
                               err_msg=f"n={n} d={d} k={k} seed={seed}")


@pytest.mark.parametrize("leaf_eval", ["scan", "kernel"])
@pytest.mark.parametrize("warm_start,best_first",
                         [(True, True), (False, False), (True, False)])
def test_tree_matches_brute_clustered(leaf_eval, warm_start, best_first, rng):
    db = clustered(rng, 3000, 32)
    q = db[::250] + 0.01 * rng.normal(size=(12, 32)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=16, block_size=64)
    eng = SearchEngine(idx, backend="tree", leaf_eval=leaf_eval,
                       warm_start=warm_start, best_first=best_first, bm=8)
    s, i, stats = eng.search(jnp.asarray(q), 10)
    sref, iref = ref.brute_force_knn(q, db, 10)
    np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5)
    assert _sets_equal(i, iref) > 0.98
    assert stats.backend == "tree"


def test_tree_matches_brute_adversarial(rng):
    """Duplicate-heavy clusters: ties and near-ties everywhere the seed,
    descent, and leaf merge could lose a candidate."""
    db = _adversarial(rng, 2400, 24)
    q = db[::200] + 0.005 * rng.normal(size=(12, 24)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=16, block_size=64)
    sref, iref = ref.brute_force_knn(q, db, 8)
    for leaf_eval in ("scan", "kernel"):
        eng = SearchEngine(idx, backend="tree", leaf_eval=leaf_eval, bm=8)
        s, i, _ = eng.search(jnp.asarray(q), 8)
        np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5,
                                   err_msg=leaf_eval)
        assert _sets_equal(i, iref) > 0.97, leaf_eval


# ---------------------------------------------------------------------------
# pruning power and stats surface
# ---------------------------------------------------------------------------

def test_tree_prunes_at_least_scan(rng):
    """Acceptance: on clustered data the tree backend's block_prune_frac is
    >= the scan backend's at equal k (its τ seed is the max of the beam
    and flat prescans, so its pruned set is a superset)."""
    db = clustered(rng, 4096, 32, n_centers=8, noise=0.04)
    q = db[rng.choice(4096, 32, replace=False)]
    q = jnp.asarray(q + 0.02 * rng.normal(size=q.shape).astype(np.float32))
    idx = build_index(jnp.asarray(db), n_pivots=16, block_size=64)
    scan = SearchEngine(idx, backend="scan")
    tree = SearchEngine(idx, backend="tree", leaf_eval="scan")
    _, _, st_s = scan.search(q, 10)
    _, _, st_t = tree.search(q, 10)
    assert float(st_t.block_prune_frac) >= float(st_s.block_prune_frac) - 1e-6
    assert float(st_t.tree_prune_frac) > 0.3, "descent must cut subtrees"
    # transitive saving: the descent evaluated well under one bound per
    # (query, node) — the thing a flat scan cannot do
    assert float(st_t.tree_node_eval_frac) < 0.9


def test_tree_stats_fields(rng):
    db = clustered(rng, 1024, 16)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=32)
    eng = SearchEngine(idx, backend="tree")
    _, _, stats = eng.search(jnp.asarray(db[:4]), 5, element_stats=True)
    assert stats.backend == "tree"
    assert 0.0 <= float(stats.tree_prune_frac) <= 1.0
    assert 0.0 <= float(stats.block_prune_frac) <= 1.0
    assert 0.0 <= float(stats.elem_prune_frac) <= 1.0
    assert 0.0 < float(stats.tree_node_eval_frac) <= 1.0
    assert stats.extras["tree_levels"] >= 1
    # dict-style access keeps working for the new field
    assert stats["tree_prune_frac"] == stats.tree_prune_frac
    # non-tree backends report None, not 0
    _, _, st_scan = SearchEngine(idx, backend="scan").search(
        jnp.asarray(db[:4]), 5)
    assert st_scan.tree_prune_frac is None


def test_tree_warm_start_seed_is_lower_bound(rng):
    """The beam-descent τ seed is a true lower bound on each query's final
    k-th best similarity (the exactness keystone of DESIGN.md §3.5)."""
    db = clustered(rng, 1024, 16)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=32)
    tree = build_tree(idx)
    qn, qp = prep_queries(idx, jnp.asarray(db[:6]))
    for k, width in [(3, 1), (10, 2), (40, 4), (70, 3)]:
        tau = np.asarray(tree_warm_start(tree, qn, qp, k, width))
        sref, _ = ref.brute_force_knn(db[:6], db, k)
        assert (tau <= sref[:, -1] + 1e-6).all(), (k, width)


def test_tree_descent_keeps_all_true_neighbors(rng):
    """No leaf holding a true top-k member is ever cut by the descent."""
    db = clustered(rng, 2048, 24)
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=64)
    tree = build_tree(idx)
    q = db[::256] + 0.01 * rng.normal(size=(8, 24)).astype(np.float32)
    qn, qp = prep_queries(idx, jnp.asarray(q))
    k = 10
    tau0 = tree_warm_start(tree, qn, qp, k, 2)
    leaf_alive, _, _ = tree_descend(tree, qp, tau0)
    alive = np.asarray(leaf_alive)
    _, iref = ref.brute_force_knn(q, db, k)
    # original row id -> padded position -> block
    row_ids = np.asarray(idx.row_ids)
    pos_of = np.full(row_ids.max() + 1, -1)
    pos_of[row_ids[row_ids >= 0]] = np.nonzero(row_ids >= 0)[0]
    blocks = pos_of[iref] // idx.block_size                    # [m, k]
    for qi in range(len(q)):
        assert alive[qi, blocks[qi]].all(), f"query {qi} lost a neighbor"


def test_tree_k_exceeds_valid_rows(rng):
    db = rng.normal(size=(40, 8)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=16)
    for leaf_eval in ("scan", "kernel"):   # kernel falls back (k > block)
        eng = SearchEngine(idx, backend="tree", leaf_eval=leaf_eval)
        s, i, _ = eng.search(jnp.asarray(db[:2]), 40)
        sref, _ = ref.brute_force_knn(db[:2], db, 40)
        np.testing.assert_allclose(np.asarray(s), sref, atol=3e-5,
                                   err_msg=leaf_eval)


def test_build_tree_rejects_sharded_index(rng):
    import jax
    db = rng.normal(size=(128, 8)).astype(np.float32)
    idx = build_index(jnp.asarray(db), n_pivots=4, block_size=32)
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), idx)
    with pytest.raises(ValueError, match="single-shard"):
        build_tree(stacked)
