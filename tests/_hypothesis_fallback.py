"""Minimal stand-in for ``hypothesis`` when it is not installed.

The container this repo targets has no network access, so optional dev
dependencies may be missing.  This shim implements just enough of the
hypothesis API used by the test suite (``given`` / ``settings`` /
``strategies.integers`` / ``strategies.floats``) to run the property tests
as seeded random sweeps with boundary values first.  When the real
hypothesis is importable it is always preferred (see conftest).
"""
from __future__ import annotations

import sys
import types

import numpy as np


class _Strategy:
    def __init__(self, sample, boundary):
        self.sample = sample          # rng -> value
        self.boundary = boundary      # list of edge-case values


def integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)), [lo, hi])


def floats(lo: float, hi: float, allow_nan: bool = False,
           allow_infinity: bool = False) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(lo, hi)),
                     [lo, hi, (lo + hi) / 2.0])


def sampled_from(elements) -> _Strategy:
    # every element is a boundary value: the sweep visits each at least
    # once before random sampling kicks in
    elements = list(elements)
    return _Strategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))],
        list(elements))


def settings(max_examples: int = 100, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        def run():
            n = getattr(run, "_max_examples",
                        getattr(fn, "_max_examples", 50))
            rng = np.random.default_rng(0)
            # boundary combos first (all-lo, all-hi, ...), then random
            width = max(len(s.boundary) for s in strategies)
            for j in range(min(width, n)):
                fn(*[s.boundary[min(j, len(s.boundary) - 1)]
                     for s in strategies])
            for _ in range(max(0, n - width)):
                fn(*[s.sample(rng) for s in strategies])
        # plain attribute copies: functools.wraps would expose the wrapped
        # signature and make pytest treat the strategy args as fixtures
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        run._max_examples = getattr(fn, "_max_examples", 50)
        return run
    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
