"""Data pipeline: determinism, resumability, shard partitioning, dedup."""
import numpy as np

from repro.data.dedup import dedup_mask, embed_tokens, find_near_duplicates
from repro.data.pipeline import ShardInfo, SyntheticLM, TokenFileSource


def test_synthetic_deterministic():
    a = SyntheticLM(100, 32, 8, seed=7)
    b = SyntheticLM(100, 32, 8, seed=7)
    for step in (0, 5, 1000):
        np.testing.assert_array_equal(a.batch(step)["tokens"],
                                      b.batch(step)["tokens"])


def test_synthetic_resume_is_stateless():
    a = SyntheticLM(100, 32, 8, seed=7)
    want = a.batch(42)
    b = SyntheticLM(100, 32, 8, seed=7)
    b.restore(a.state())
    np.testing.assert_array_equal(b.batch(42)["tokens"], want["tokens"])


def test_synthetic_shards_partition_global_batch():
    full = SyntheticLM(100, 16, 8, seed=3)
    parts = [SyntheticLM(100, 16, 8, seed=3,
                         shard=ShardInfo(i, 4)).batch(5)["tokens"]
             for i in range(4)]
    assert all(p.shape == (2, 16) for p in parts)
    # shards are distinct (not copies of each other)
    assert not np.array_equal(parts[0], parts[1])


def test_token_file_source(tmp_path):
    path = str(tmp_path / "toks.bin")
    rng = np.random.default_rng(0)
    data = rng.integers(0, 1000, size=170 * 17, dtype=np.int32)
    data.tofile(path)
    src = TokenFileSource(path, 16, 8, seed=1)
    b0 = src.batch(0)
    assert b0["tokens"].shape == (8, 16)
    np.testing.assert_array_equal(src.batch(0)["tokens"], b0["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])
    # different steps give different samples; epoch wraps don't crash
    many = {src.batch(s)["tokens"].tobytes() for s in range(6)}
    assert len(many) > 1


def test_dedup_finds_planted_duplicates(rng):
    toks = rng.integers(0, 500, size=(60, 64))
    toks[13] = toks[4]          # exact duplicate
    toks[27, :60] = toks[9, :60]  # near duplicate
    emb = embed_tokens(toks)
    pairs, stats = find_near_duplicates(emb, threshold=0.9, k=4,
                                        n_pivots=8, block_size=32)
    flat = set(pairs)
    assert (4, 13) in flat
    assert (9, 27) in flat
    keep = dedup_mask(60, pairs)
    assert not keep[13] and keep[4]
