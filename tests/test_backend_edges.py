"""Edge-case sweep over ALL backends: k at and beyond the datastore size,
plus single-query batches — pinning the ``(-inf, -1)``-fill contract that
``SearchEngine.search`` documents and brute equality on the valid prefix.

Regression context (PR 5): ``brute_search`` used to crash with "top_k must
be no larger than minor dimension" whenever ``k`` exceeded the padded row
count — and ``auto_backend`` routes exactly the tiny datastores where
``k > n`` is most likely to brute.  The engine now clamps every backend's
inner ``top_k`` to the slot count and pads the tail.  Constructing an
engine from a flat 2D index plus a mesh used to die mid-trace in an opaque
reshape TypeError; it now raises at construction.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ref
from repro.core.index import build_index
from repro.search import SearchEngine

N_ROWS, DIM, BLOCK = 100, 16, 32        # n_pad = 128: k can straddle both
BACKENDS = ("scan", "kernel", "brute", "tree", "sharded", "sharded_tree")


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(5)
    db = ref.normalize(rng.normal(size=(N_ROWS, DIM))).astype(np.float32)
    q = ref.normalize(db[::41] + 0.01 * rng.normal(size=(3, DIM))
                      ).astype(np.float32)
    return db, q


def make_engine(backend: str, db) -> SearchEngine:
    if backend in ("sharded", "sharded_tree"):
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        return SearchEngine.build(db, n_pivots=8, block_size=BLOCK,
                                  mesh=mesh,
                                  tree_shards=backend == "sharded_tree")
    # interpret=True pins the kernel path off-TPU; tree always descends
    return SearchEngine.build(db, n_pivots=8, block_size=BLOCK,
                              backend=backend, interpret=True)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k", (N_ROWS, N_ROWS + 10, 130, 200),
                         ids=("k_eq_nvalid", "k_gt_nvalid", "k_gt_npad",
                              "k_way_past"))
def test_k_edge_fill_contract(corpus, backend, k):
    db, q = corpus
    eng = make_engine(backend, db)
    sims, ids, stats = eng.search(jnp.asarray(q), k)
    sims, ids = np.asarray(sims), np.asarray(ids)
    assert sims.shape == (len(q), k) and ids.shape == (len(q), k)

    # valid prefix equals fp64 brute force (tie-aware id equality)
    sref, iref = ref.brute_force_knn(q, db, N_ROWS)
    np.testing.assert_allclose(sims[:, :N_ROWS], sref, atol=3e-5,
                               err_msg=f"{backend} k={k}")
    assert (np.sort(ids[:, :N_ROWS], 1) == np.sort(iref, 1)).all(), (
        backend, k)

    # every slot past the valid rows carries the (-inf, -1) fill
    assert (ids[:, N_ROWS:] == -1).all(), (backend, k, ids[:, N_ROWS - 2:])
    assert np.isneginf(sims[:, N_ROWS:]).all(), (backend, k)
    # and no -1 leaks into the valid prefix
    assert (ids[:, :N_ROWS] >= 0).all(), (backend, k)
    assert stats.k == k


@pytest.mark.parametrize("backend", BACKENDS)
def test_single_query_batch(corpus, backend):
    """m == 1: the degenerate batch every tile/merge path must accept."""
    db, q = corpus
    eng = make_engine(backend, db)
    sims, ids, _ = eng.search(jnp.asarray(q[:1]), 10)
    sref, iref = ref.brute_force_knn(q[:1], db, 10)
    np.testing.assert_allclose(np.asarray(sims), sref, atol=3e-5)
    assert (np.sort(np.asarray(ids), 1) == np.sort(iref, 1)).all()


def test_brute_k_beyond_padded_rows_regression(corpus):
    """The reported crash verbatim: k=130 on a 100-row datastore, routed to
    brute by auto-selection (pre-PR: ValueError from lax.top_k)."""
    db, q = corpus
    eng = SearchEngine.build(db, n_pivots=8, block_size=BLOCK)
    assert eng.backend_name == "brute"          # tiny datastore -> brute
    sims, ids, _ = eng.search(jnp.asarray(q), 130)
    assert np.asarray(sims).shape == (len(q), 130)
    assert (np.asarray(ids)[:, N_ROWS:] == -1).all()


def test_flat_index_plus_mesh_raises_regression(corpus):
    """Flat 2D BlockIndex + mesh used to auto-select 'sharded' and die in
    an opaque 'cannot reshape array' TypeError mid-trace; it must raise a
    clear construction-time error pointing at the sharded build."""
    db, _ = corpus
    idx = build_index(jnp.asarray(db), n_pivots=8, block_size=BLOCK)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    with pytest.raises(ValueError, match="shard-stacked"):
        SearchEngine(idx, mesh=mesh)
    # explicit flat backend with a (useless) mesh still works
    eng = SearchEngine(idx, mesh=mesh, backend="scan")
    sims, ids, _ = eng.search(jnp.asarray(db[:2]), 3)
    assert int(np.asarray(ids)[0, 0]) == 0


def test_stacked_index_needs_sharded_backend():
    """The mirror-image construction slip: a shard-stacked index handed to
    a flat backend raises instead of reshaping garbage."""
    from repro.core.distributed import build_sharded_index
    rng = np.random.default_rng(6)
    db = rng.normal(size=(64, 8)).astype(np.float32)
    sidx = build_sharded_index(db, 2, n_pivots=4, block_size=16)
    with pytest.raises(ValueError, match="sharded"):
        SearchEngine(sidx, backend="scan")


def test_stacked_index_without_mesh_raises_at_search():
    """A shard-stacked index with no mesh constructs (auto -> sharded) but
    must fail with the clear 'needs mesh' error at search, not an opaque
    shard_map trace error."""
    from repro.core.distributed import build_sharded_index
    rng = np.random.default_rng(7)
    db = rng.normal(size=(64, 8)).astype(np.float32)
    sidx = build_sharded_index(db, 2, n_pivots=4, block_size=16)
    eng = SearchEngine(sidx)
    assert eng.backend_name == "sharded"
    with pytest.raises(ValueError, match="mesh"):
        eng.search(jnp.asarray(db[:2]), 3)
