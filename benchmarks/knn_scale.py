"""Search throughput scaling: block-pruned vs brute-force exact kNN.

Wall-clock on this CPU host (XLA jit, single core) across datastore sizes,
all through the unified :class:`SearchEngine`.  The derived column reports
the *work avoided* (tiles or blocks pruned), which is hardware-independent,
alongside the measured speedup here.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ref
from repro.core.index import build_index
from repro.search import SearchEngine


def _time(f, *args, reps=3):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def run(sizes=(4096, 16384), d: int = 64, k: int = 10, m: int = 64):
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        c = ref.normalize(rng.normal(size=(16, d)))
        db = ref.normalize(c[rng.integers(0, 16, n)] +
                           0.05 * rng.normal(size=(n, d))).astype(np.float32)
        q = jnp.asarray(db[rng.choice(n, m, replace=False)])
        idx = build_index(jnp.asarray(db), n_pivots=16, block_size=128)
        brute = SearchEngine(idx, backend="brute")
        base = SearchEngine(idx, backend="scan", warm_start=False,
                            best_first=False)
        eng = SearchEngine(idx, backend="scan")
        t_brute = _time(lambda: brute.search(q, k)[:2])
        t_base = _time(lambda: base.search(q, k)[:2])
        t_eng = _time(lambda: eng.search(q, k)[:2])
        _, _, st_base = base.search(q, k)
        _, _, st_eng = eng.search(q, k)
        rows.append((f"knn_scale/n{n}/brute_us", t_brute * 1e6, ""))
        rows.append((f"knn_scale/n{n}/pruned_us", t_base * 1e6,
                     f"block_prune_frac={st_base.block_prune_frac:.3f}"))
        rows.append((f"knn_scale/n{n}/engine_us", t_eng * 1e6,
                     f"warm-start+best-first, block_prune_frac="
                     f"{st_eng.block_prune_frac:.3f}"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")
