"""Search throughput scaling: block-pruned vs brute-force exact kNN.

Wall-clock on this CPU host (XLA jit, single core) across datastore sizes,
all through the unified :class:`SearchEngine`.  The derived column reports
the *work avoided* (tiles or blocks pruned), which is hardware-independent,
alongside the measured p50 here.

Timing goes through :mod:`benchmarks.timing` — the old ad-hoc helper here
averaged reps behind a single warmup call without recording per-rep
samples, so one descheduled rep skewed the mean and compile time was
invisible; :func:`benchmarks.timing.measure` separates warmup from
individually-blocked reps and reports the robust p50.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":       # runnable from anywhere, TPU probe pinned off
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

import jax.numpy as jnp
import numpy as np

from benchmarks.timing import measure
from repro.core import ref
from repro.core.index import build_index
from repro.search import SearchEngine


def run(sizes=(4096, 16384), d: int = 64, k: int = 10, m: int = 64):
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        c = ref.normalize(rng.normal(size=(16, d)))
        db = ref.normalize(c[rng.integers(0, 16, n)] +
                           0.05 * rng.normal(size=(n, d))).astype(np.float32)
        q = jnp.asarray(db[rng.choice(n, m, replace=False)])
        idx = build_index(jnp.asarray(db), n_pivots=16, block_size=128)
        brute = SearchEngine(idx, backend="brute")
        base = SearchEngine(idx, backend="scan", warm_start=False,
                            best_first=False)
        eng = SearchEngine(idx, backend="scan")
        t_brute = measure(lambda: brute.search(q, k)[:2], warmup=2, reps=5)
        t_base = measure(lambda: base.search(q, k)[:2], warmup=2, reps=5)
        t_eng = measure(lambda: eng.search(q, k)[:2], warmup=2, reps=5)
        _, _, st_base = base.search(q, k)
        _, _, st_eng = eng.search(q, k)
        rows.append((f"knn_scale/n{n}/brute_us", t_brute.p50_us, ""))
        rows.append((f"knn_scale/n{n}/pruned_us", t_base.p50_us,
                     f"block_prune_frac={st_base.block_prune_frac:.3f}"))
        rows.append((f"knn_scale/n{n}/engine_us", t_eng.p50_us,
                     f"tuned defaults, block_prune_frac="
                     f"{st_eng.block_prune_frac:.3f}"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")
