"""Search throughput scaling: block-pruned vs brute-force exact kNN.

Wall-clock on this CPU host (XLA jit, single core) across datastore sizes.
The derived column reports the *work avoided* (tiles or blocks pruned),
which is hardware-independent, alongside the measured speedup here.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ref
from repro.core.index import build_index, search, search_brute


def _time(f, *args, reps=3):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def run(sizes=(4096, 16384), d: int = 64, k: int = 10, m: int = 64):
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        c = ref.normalize(rng.normal(size=(16, d)))
        db = ref.normalize(c[rng.integers(0, 16, n)] +
                           0.05 * rng.normal(size=(n, d))).astype(np.float32)
        q = jnp.asarray(db[rng.choice(n, m, replace=False)])
        idx = build_index(jnp.asarray(db), n_pivots=16, block_size=128)
        t_brute = _time(lambda: search_brute(idx, q, k))
        t_pruned = _time(lambda: search(idx, q, k))
        _, _, stats = search(idx, q, k)
        rows.append((f"knn_scale/n{n}/brute_us", t_brute * 1e6, ""))
        rows.append((f"knn_scale/n{n}/pruned_us", t_pruned * 1e6,
                     f"block_prune_frac={float(stats['block_prune_frac']):.3f}"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")
