"""§Roofline: compute / memory / collective terms from the dry-run artifacts.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  Sources per cell:

  * HLO FLOPs / bytes — from the *unrolled probes*: ``lower_cell`` lowers the
    model with k = pattern-length and 2k layers unrolled; per-layer cost is
    (cost_2k - cost_k) / k and the base (embed/head/loss) is cost_k - k*per.
    This sidesteps XLA's while-loop cost analysis, which counts a scan body
    once regardless of trip count.
  * collective bytes — same extrapolation over the parsed HLO collectives.
  * per-device memory — from the full (scanned) model's memory_analysis.

Terms (seconds per executed step, per device):
  compute    = HLO_FLOPs / 197e12
  memory     = HLO_bytes / 819e9
  collective = collective_bytes / 50e9

MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode) with N = active params;
the MODEL_FLOPS/HLO_FLOPs ratio exposes remat/dispatch/replication waste.
"""
from __future__ import annotations

import json
import math
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def _cost(rec):
    c = rec.get("cost", {})
    coll = sum(v["bytes"] for v in rec.get("collectives", {}).values())
    return (c.get("flops", 0.0), c.get("bytes accessed", 0.0), float(coll))


def _layers(arch_cfg_layers, pattern_len, unrolled_layers):
    return unrolled_layers


def cell_terms(rec: dict) -> dict | None:
    """Extrapolated per-device terms for one cell record (needs probes)."""
    if "probe" not in rec or "error" in rec:
        return None
    p1 = rec["probe"]
    p2 = rec.get("probe2")
    from repro.configs import ARCHS
    cfg = ARCHS[rec["arch"]]
    k = len(cfg.block_pattern)
    L = cfg.n_layers
    f1, b1, c1 = _cost(p1)
    if p2 is not None:
        f2, b2, c2 = _cost(p2)
        per = tuple((x2 - x1) / k for x1, x2 in ((f1, f2), (b1, b2), (c1, c2)))
        base = tuple(x1 - k * p for x1, p in zip((f1, b1, c1), per))
    else:  # fall back: attribute everything to layers (overcounts base)
        per = tuple(x / k for x in (f1, b1, c1))
        base = (0.0, 0.0, 0.0)
    scale = L / 1.0
    flops = max(base[0] + per[0] * L, 0.0)
    bytes_ = max(base[1] + per[1] * L, 0.0)
    coll = max(base[2] + per[2] * L, 0.0)

    shape_kind = {"train_4k": "train", "prefill_32k": "prefill",
                  "decode_32k": "decode", "long_500k": "decode"}[rec["shape"]]
    n_act = rec.get("active_params", cfg.active_param_count())
    from repro.configs import SHAPES
    shp = SHAPES[rec["shape"]]
    n_dev = math.prod(rec["mesh"].values())
    if shape_kind == "train":
        model_flops = 6.0 * n_act * shp.batch * shp.seq
    elif shape_kind == "prefill":
        model_flops = 2.0 * n_act * shp.batch * shp.seq
    else:
        model_flops = 2.0 * n_act * shp.batch          # one token / sequence
    model_flops_dev = model_flops / n_dev

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / LINK_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])
    t_max = dominant[1] if dominant[1] > 0 else float("inf")
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "x".join(str(v) for v in rec["mesh"].values()),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant[0],
        "hlo_flops_dev": flops, "hlo_bytes_dev": bytes_, "coll_bytes_dev": coll,
        "model_flops_dev": model_flops_dev,
        "useful_flops_ratio": model_flops_dev / flops if flops else 0.0,
        "roofline_fraction": (model_flops_dev / PEAK_FLOPS) / t_max,
        "mem_gib_dev": (rec["memory"]["argument_bytes"]
                        + rec["memory"]["temp_bytes"]) / 2**30,
    }


def load_all(mesh_kind: str = "pod"):
    d = os.path.join(DRYRUN_DIR, mesh_kind)
    out = []
    if not os.path.isdir(d):
        return out
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(d, name)) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "skipped": rec["reason"]})
            continue
        t = cell_terms(rec)
        if t:
            out.append(t)
        else:
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "error": rec.get("error", "no probe")[:120]})
    return out


def run():
    rows = []
    for cell in load_all("pod"):
        tag = f"roofline/{cell['arch']}/{cell['shape']}"
        if "skipped" in cell:
            rows.append((tag + "/skip", 0.0, cell["skipped"]))
            continue
        if "error" in cell:
            rows.append((tag + "/error", -1.0, cell["error"]))
            continue
        rows.append((tag + "/dominant_" + cell["dominant"],
                     cell["roofline_fraction"],
                     f"compute={cell['t_compute_s']:.2e}s "
                     f"mem={cell['t_memory_s']:.2e}s "
                     f"coll={cell['t_collective_s']:.2e}s "
                     f"useful={cell['useful_flops_ratio']:.2f}"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.4f},{note}")
