"""Pruning power in actual index structures — the paper's deferred
experiment (§4: "we will not investigate the actual performance in a
similarity index here, but plan to do this in future work").

Four structures x two bound families, on three data regimes:
  * VP-tree (paper-faithful CPU index): exact-similarity fraction computed
    with the Eq. 13 (mult) vs reverse-Eq. 7 (euclid) subtree bounds,
  * scalar LAESA (per-point pivot table): the reference pruning ceiling,
  * the unified SearchEngine (scan + Pallas kernel backends), natural-order
    baseline vs τ warm-start + best-first block ordering,
  * the array-encoded pivot tree (``backend="tree"``, DESIGN.md §3.5):
    transitive Eq. 13 descent over block subtrees — the TPU-shaped
    answer to the VP-tree, measured on the same regimes,
  * the sharded datastore (``backend="sharded"``) over a mesh of every
    visible device (one on the CI bench runner, eight in the multidevice
    job): flat per-shard scan vs the per-shard tree descent with the
    broadcast global τ (``tree_shards=True``, DESIGN.md §3.6).

``*_matches_brute`` rows are exactness gates (1.0 = identical result set
to float64 brute force); ``tools/check_bench_regression.py`` hard-fails
CI when any of them moves off 1.0, and tolerance-bands the fractions.

Regimes: uniform high-dim (concentration -> little pruning, expected per the
paper's own curse-of-dimensionality discussion), clustered embeddings (the
realistic neural-embedding case), and the dedup regime (threshold ~ 1).

``--quick`` runs a smaller instance of the clustered regime only (CI smoke).
``--json PATH`` additionally writes the rows as a machine-readable baseline
(the checked-in ``BENCH_pruning.json`` gives future PRs a perf trajectory).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ref
from repro.core.index import build_index
from repro.core.vptree import VPTree
from repro.search import SearchEngine


def _datasets(n=3000, d=64, seed=0, regimes=("uniform", "clustered", "dedup")):
    rng = np.random.default_rng(seed)
    out = {}
    if "uniform" in regimes:
        out["uniform"] = ref.normalize(rng.normal(size=(n, d))).astype(np.float32)
    c = ref.normalize(rng.normal(size=(8, d)))
    clu = ref.normalize(
        c[rng.integers(0, 8, n)] + 0.05 * rng.normal(size=(n, d))
    ).astype(np.float32)
    if "clustered" in regimes:
        out["clustered"] = clu
    if "dedup" in regimes:
        dup = clu.copy()
        dup[n // 2:] = dup[: n - n // 2] + 1e-3 * rng.normal(
            size=(n - n // 2, d)).astype(np.float32)   # near-duplicate regime
        out["dedup"] = dup
    return out


def _multiprocess_exactness() -> float:
    """The multi-host exactness gate row (DESIGN.md §3.7).

    Runs ``tools/multiprocess_smoke.py`` — 2 worker processes with their
    own ``jax.distributed.initialize`` and virtual CPU devices, building
    the index process-locally — whose workers assert bit-identity to the
    single-process sharded backend and brute force.  1.0 iff every worker
    passed; any crash or mismatch is 0.0, which
    ``tools/check_bench_regression.py`` hard-fails (the row is in its
    REQUIRED_EXACTNESS set, so silently dropping it also fails).  Sized
    small: this row gates exactness across process boundaries, not
    pruning power.
    """
    smoke = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                         "tools", "multiprocess_smoke.py")
    size = ["--rows", "603", "--dim", "16", "--queries", "5",
            "--block-size", "32", "--pivots", "8"]
    with tempfile.TemporaryDirectory(prefix="bench_mp_") as tmp:
        out = os.path.join(tmp, "mp.json")
        try:
            r = subprocess.run(
                [sys.executable, smoke, "--processes", "2", "--devices", "2",
                 "--json", out] + size, timeout=900,
                # re-pin the CPU backend for the spawned fleet: a worker
                # inheriting an unset JAX_PLATFORMS would stall in
                # TPU-plugin autodetection on metadata retries
                env={**os.environ, "JAX_PLATFORMS": "cpu"})
            if r.returncode != 0:
                return 0.0
            with open(out) as f:
                return float(json.load(f)["metrics"][0]["value"])
        except (subprocess.TimeoutExpired, OSError, KeyError, ValueError):
            return 0.0


def _matches_brute(sims, db, q, k) -> float:
    """Exactness gate: 1.0 iff the similarity profile equals fp64 brute
    force (set-identical results; id permutations on ties are fine)."""
    sref, _ = ref.brute_force_knn(np.asarray(q), db, k)
    return float(np.allclose(np.asarray(sims), sref, atol=3e-5))


def run(k: int = 10, n_queries: int = 32, *, quick: bool = False):
    rows = []
    rng = np.random.default_rng(1)
    data = (_datasets(n=1024, regimes=("clustered",)) if quick
            else _datasets())
    for regime, db in data.items():
        q = db[rng.choice(len(db), n_queries, replace=False)]
        q = ref.normalize(q + 0.01 * rng.normal(size=q.shape)).astype(np.float32)

        if not quick:
            vt = VPTree(db, leaf_size=16)
            _, _, f_mult = vt.knn_batch(q, k, bound="mult")
            _, _, f_eucl = vt.knn_batch(q, k, bound="euclid")
            rows.append((f"pruning/{regime}/vptree_exact_frac_mult", f_mult,
                         "lower = better pruning"))
            rows.append((f"pruning/{regime}/vptree_exact_frac_euclid", f_eucl,
                         "mult <= euclid expected"))

            piv = db[rng.choice(len(db), 16, replace=False)]
            _, _, f_laesa = ref.pruned_knn_reference(q[:8], db, piv, k)
            rows.append((f"pruning/{regime}/laesa_exact_frac", f_laesa,
                         "scalar per-point ceiling"))

        idx = build_index(jnp.asarray(db), n_pivots=16, block_size=64)
        qj = jnp.asarray(q)

        # natural-order scan, no warm start: the pre-engine baseline
        base = SearchEngine(idx, backend="scan", warm_start=False,
                            best_first=False)
        _, _, st0 = base.search(qj, k, element_stats=True)
        rows.append((f"pruning/{regime}/block_prune_frac",
                     st0.block_prune_frac, "scan, natural order (baseline)"))
        rows.append((f"pruning/{regime}/elem_prunable_frac",
                     st0.elem_prune_frac, "per-element bound ceiling"))

        # engine defaults: τ warm-start + best-first block ordering
        eng = SearchEngine(idx, backend="scan")
        s_scan, _, st1 = eng.search(qj, k)
        rows.append((f"pruning/{regime}/block_prune_frac_engine",
                     st1.block_prune_frac,
                     "scan, tau warm-start + best-first"))
        rows.append((f"pruning/{regime}/scan_matches_brute",
                     _matches_brute(s_scan, db, q, k),
                     "exactness gate: must be 1.0"))

        # multi-pivot joint-bound intersection (DESIGN.md §3.8): the
        # uniform/high-d regime, where Eq. 13 intervals concentrate toward
        # the full pivot-similarity spread and prune nothing, is exactly
        # where intersecting the joint k-pivot projection cap still bites.
        # The index is rebuilt at the coverage suggestion so the bound
        # table is wide enough, and the knob is explicit: the time-tuned
        # default stays 0 on compute-and-mask CPU, where the cap matmul
        # costs flops it cannot skip (tools/tune_defaults.py measures
        # this; the row reports what a lazy evaluator would avoid).
        from repro.core.pivots import suggest_bound_pivots
        npv = suggest_bound_pivots(len(db), db.shape[1])
        midx = build_index(jnp.asarray(db), n_pivots=npv, block_size=64)
        meng = SearchEngine(midx, backend="scan", n_pivots=npv)
        s_mp, _, st_mp = meng.search(qj, k)
        rows.append((f"pruning/{regime}/block_prune_frac_multipivot",
                     st_mp.block_prune_frac,
                     "scan with the joint multi-pivot cap intersected"))
        rows.append((f"pruning/{regime}/multipivot_n_pivots",
                     float(st_mp.n_pivots),
                     "joint-bound depth the engine resolved (explicit)"))
        rows.append((f"pruning/{regime}/multipivot_matches_brute",
                     _matches_brute(s_mp, db, q, k),
                     "exactness gate: must be 1.0"))

        # pivot tree: transitive Eq. 13 descent, flat scan leaf stage
        treng = SearchEngine(idx, backend="tree", leaf_eval="scan")
        s_tree, _, st_t = treng.search(qj, k)
        rows.append((f"pruning/{regime}/tree_prune_frac",
                     st_t.tree_prune_frac,
                     "pivot-tree transitive descent alone"))
        rows.append((f"pruning/{regime}/block_prune_frac_tree",
                     st_t.block_prune_frac,
                     "tree total (descent + leaf stage); >= scan engine"))
        rows.append((f"pruning/{regime}/tree_node_eval_frac",
                     st_t.tree_node_eval_frac,
                     "bound evals the descent needed (lower = better)"))
        rows.append((f"pruning/{regime}/tree_matches_brute",
                     _matches_brute(s_tree, db, q, k),
                     "exactness gate: must be 1.0"))

        # pivot tree with the Pallas leaf-gather stage: the kernel grid
        # shrinks to the union of surviving leaves
        trk = SearchEngine(idx, backend="tree", leaf_eval="kernel", bm=8)
        s_trk, _, st_k = trk.search(qj, k)
        rows.append((f"pruning/{regime}/tree_kernel_tile_computed_frac",
                     st_k.tile_computed_frac,
                     "Pallas leaf-gather stage, over the full grid"))
        rows.append((f"pruning/{regime}/tree_kernel_matches_brute",
                     _matches_brute(s_trk, db, q, k),
                     "exactness gate: must be 1.0"))

        # sharded datastore over every visible device: flat per-shard scan
        # vs the per-shard tree descent with the broadcast global tau, on
        # the SAME placed index.  The per-shard trees must prune at least
        # what the flat path does (DESIGN.md §3.6) — the tree_prune_frac >=
        # sharded block_prune_frac ordering is part of what the regression
        # gate watches.
        from repro.core.distributed import (build_sharded_index,
                                            place_sharded_index)
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        sidx = place_sharded_index(
            build_sharded_index(db, mesh.devices.size, n_pivots=16,
                                block_size=64), mesh)
        shf = SearchEngine(sidx, mesh=mesh, tree_shards=False)
        s_shf, _, st_sf = shf.search(qj, k)
        rows.append((f"pruning/{regime}/sharded_block_prune_frac",
                     st_sf.block_prune_frac,
                     "sharded, flat per-shard scan"))
        rows.append((f"pruning/{regime}/sharded_matches_brute",
                     _matches_brute(s_shf, db, q, k),
                     "exactness gate: must be 1.0"))
        sht = SearchEngine(sidx, mesh=mesh, tree_shards=True)
        s_sht, _, st_st = sht.search(qj, k)
        rows.append((f"pruning/{regime}/sharded_tree_prune_frac",
                     st_st.tree_prune_frac,
                     "per-shard transitive descent alone (global tau)"))
        rows.append((f"pruning/{regime}/sharded_tree_block_prune_frac",
                     st_st.block_prune_frac,
                     "sharded tree total; >= flat sharded"))
        rows.append((f"pruning/{regime}/sharded_tree_node_eval_frac",
                     st_st.tree_node_eval_frac,
                     "bound evals the per-shard descents needed"))
        rows.append((f"pruning/{regime}/sharded_tree_matches_brute",
                     _matches_brute(s_sht, db, q, k),
                     "exactness gate: must be 1.0"))

        kern0 = SearchEngine(idx, backend="kernel", bm=8, warm_start=False,
                             best_first=False)
        _, _, kt0 = kern0.search(qj, k)
        rows.append((f"pruning/{regime}/kernel_tile_computed_frac",
                     kt0.tile_computed_frac, "Pallas kernel, bm=8 (baseline)"))
        kern1 = SearchEngine(idx, backend="kernel", bm=8)
        _, _, kt1 = kern1.search(qj, k, element_stats=True)
        rows.append((f"pruning/{regime}/kernel_tile_computed_frac_engine",
                     kt1.tile_computed_frac,
                     "Pallas kernel, bm=8, warm-start + best-first"))
        # backend-uniform element counter: kernel vs scan should agree at
        # matched granularity (tests pin this; here it is tracked over time)
        rows.append((f"pruning/{regime}/kernel_elem_prune_frac",
                     kt1.elem_prune_frac,
                     "per-element Eq.13 pruning seen by the kernel"))

    # multi-host: one regime-independent exactness gate — the 2-process
    # smoke whose workers assert bit-identity to the single-process
    # sharded path (and brute force) after a process-local index build.
    # Full runs only: quick mode is the per-python-matrix CI smoke, and
    # the dedicated multiprocess CI job already runs the fleet there
    # (check_bench_regression requires this row from full runs only).
    if not quick:
        rows.append(("pruning/multihost/multiprocess_matches_brute",
                     _multiprocess_exactness(),
                     "2-process distributed build; exactness gate: "
                     "must be 1.0"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small clustered-only smoke run (CI)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write rows as JSON (the BENCH_pruning.json "
                         "baseline format)")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    for name, val, note in rows:
        print(f"{name},{val:.4f},{note}")
    if args.json:
        payload = {
            "benchmark": "pruning_power",
            "quick": args.quick,
            "metrics": [{"name": n, "value": round(float(v), 4), "note": t}
                        for n, v, t in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json} ({len(rows)} metrics)")
