"""Pruning power in actual index structures — the paper's deferred
experiment (§4: "we will not investigate the actual performance in a
similarity index here, but plan to do this in future work").

Three structures x two bound families, on three data regimes:
  * VP-tree (paper-faithful CPU index): exact-similarity fraction computed
    with the Eq. 13 (mult) vs reverse-Eq. 7 (euclid) subtree bounds,
  * scalar LAESA (per-point pivot table): the reference pruning ceiling,
  * TPU block index + Pallas kernel: fraction of MXU tiles computed.

Regimes: uniform high-dim (concentration -> little pruning, expected per the
paper's own curse-of-dimensionality discussion), clustered embeddings (the
realistic neural-embedding case), and the dedup regime (threshold ~ 1).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import ref
from repro.core.index import build_index, search
from repro.core.vptree import VPTree
from repro.kernels import ops


def _datasets(n=3000, d=64, seed=0):
    rng = np.random.default_rng(seed)
    uni = ref.normalize(rng.normal(size=(n, d))).astype(np.float32)
    c = ref.normalize(rng.normal(size=(8, d)))
    clu = ref.normalize(
        c[rng.integers(0, 8, n)] + 0.05 * rng.normal(size=(n, d))
    ).astype(np.float32)
    dup = clu.copy()
    dup[n // 2:] = dup[: n - n // 2] + 1e-3 * rng.normal(
        size=(n - n // 2, d)).astype(np.float32)   # near-duplicate regime
    return {"uniform": uni, "clustered": clu, "dedup": dup}


def run(k: int = 10, n_queries: int = 32):
    rows = []
    rng = np.random.default_rng(1)
    for regime, db in _datasets().items():
        q = db[rng.choice(len(db), n_queries, replace=False)]
        q = ref.normalize(q + 0.01 * rng.normal(size=q.shape)).astype(np.float32)

        vt = VPTree(db, leaf_size=16)
        _, _, f_mult = vt.knn_batch(q, k, bound="mult")
        _, _, f_eucl = vt.knn_batch(q, k, bound="euclid")
        rows.append((f"pruning/{regime}/vptree_exact_frac_mult", f_mult,
                     "lower = better pruning"))
        rows.append((f"pruning/{regime}/vptree_exact_frac_euclid", f_eucl,
                     "mult <= euclid expected"))

        piv = db[rng.choice(len(db), 16, replace=False)]
        _, _, f_laesa = ref.pruned_knn_reference(q[:8], db, piv, k)
        rows.append((f"pruning/{regime}/laesa_exact_frac", f_laesa,
                     "scalar per-point ceiling"))

        idx = build_index(jnp.asarray(db), n_pivots=16, block_size=64)
        _, _, stats = search(idx, jnp.asarray(q), k, element_stats=True)
        rows.append((f"pruning/{regime}/block_prune_frac",
                     float(stats["block_prune_frac"]),
                     "TPU block granularity"))
        rows.append((f"pruning/{regime}/elem_prunable_frac",
                     float(stats["elem_prune_frac"]),
                     "per-element bound ceiling"))

        _, _, tile_frac = ops.search_index(idx, jnp.asarray(q), k, bm=8)
        rows.append((f"pruning/{regime}/kernel_tile_computed_frac",
                     float(tile_frac), "Pallas kernel, bm=8"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.4f},{note}")
