"""Paper §4.2 / Fig. 5: Mult vs Arccos agreement at fp precision.

The paper reports |Mult - Arccos| ~ 1e-16 over the grid (fp64).  We measure
the max/mean absolute difference over (a) the full grid and (b) a cluster of
near-1 similarities — the catastrophic-cancellation zone the paper worries
about in the (1 - sim^2) radicand — plus the fp32 behaviour that matters on
TPU (the kernel's margin of 4e-7 ~ 4 ulp covers it).
"""
from __future__ import annotations

import numpy as np

from repro.core import ref


def run(grid: int = 401):
    g = np.linspace(-1, 1, grid)
    A, B = np.meshgrid(g, g)
    d64 = np.abs(ref.lb_mult(A, B) - ref.lb_arccos(A, B))
    # mid-range (well-conditioned for arccos): the paper's 1e-16 regime
    mid = (np.abs(A) < 0.9) & (np.abs(B) < 0.9)

    rng = np.random.default_rng(0)
    a = 1 - 10 ** rng.uniform(-16, -1, 100_000)
    b = 1 - 10 ** rng.uniform(-16, -1, 100_000)
    d_hi = np.abs(ref.lb_mult(a, b) - ref.lb_arccos(a, b))

    a32, b32 = a.astype(np.float32), b.astype(np.float32)
    m32 = (a32 * b32 - np.sqrt(np.maximum(0, 1 - a32 * b32 * 0 - a32**2))
           * np.sqrt(np.maximum(0, 1 - b32**2))).astype(np.float64)
    d32 = np.abs(m32 - ref.lb_mult(a, b))

    return [
        ("stability/max_absdiff_grid_mid_fp64", float(d64[mid].max()),
         "paper: ~1e-16"),
        ("stability/mean_absdiff_grid_fp64", float(d64.mean()), ""),
        ("stability/max_absdiff_near1_fp64", float(d_hi.max()),
         "cancellation zone; arccos conditioning dominates"),
        ("stability/max_err_near1_fp32", float(d32.max()),
         "fp32 kernel regime; < pruning margin 4e-7 * k"),
        ("stability/no_nans", float(not (np.isnan(d64).any()
                                         or np.isnan(d_hi).any())), ""),
    ]


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.3e},{note}")
