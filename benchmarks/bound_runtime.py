"""Paper Table 2: runtime of each bound equation.

The paper benchmarks scalar Java (JMH) latency; the TPU-relevant analogue is
*vectorized throughput*: ns per element over a 2M-element array, jit'd jnp on
this host (CPU here; the relative ordering — Mult ~ cheap forms << Arccos —
is the paper's claim, and is what carries to the TPU VPU where transcendental
ops cost even more relative to mul/rsqrt).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds

N = 2_000_000
REPS = 5


def _bench(fn, a, b) -> float:
    f = jax.jit(fn)
    f(a, b).block_until_ready()          # compile
    t0 = time.perf_counter()
    for _ in range(REPS):
        f(a, b).block_until_ready()
    return (time.perf_counter() - t0) / REPS / a.size * 1e9   # ns/elem


def run():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(-1, 1, N), jnp.float64)
    b = jnp.asarray(rng.uniform(-1, 1, N), jnp.float64)
    rows = []
    baseline = _bench(lambda x, y: x + y, a, b)
    rows.append(("runtime/baseline_add_ns", baseline, "paper: 8.19 ns scalar"))
    table = [
        ("euclidean", bounds.lb_euclid, "paper: 10.36 ns"),
        ("eucl_lb", bounds.lb_euclid_fast, "paper: 10.17 ns"),
        ("arccos", bounds.lb_arccos, "paper: 610.3 ns (jdk) / 59.0 (jafama)"),
        ("mult", bounds.lb_mult, "paper: 9.75 ns (recommended)"),
        ("mult_lb1", bounds.lb_mult_fast1, "paper: 10.31 ns"),
        ("mult_lb2", bounds.lb_mult_fast2, "paper: 8.55 ns"),
        ("ub_mult", bounds.ub_mult, "kernel pruning bound"),
    ]
    arccos_ns = mult_ns = None
    for name, fn, note in table:
        ns = _bench(fn, a, b)
        rows.append((f"runtime/{name}_ns", ns, note))
        if name == "arccos":
            arccos_ns = ns
        if name == "mult":
            mult_ns = ns
    rows.append(("runtime/arccos_over_mult", arccos_ns / mult_ns,
                 "paper: ~62x (jdk) / 6x (jafama); Mult must win"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.3f},{note}")
