"""Ablation: pruning power vs intrinsic dimensionality and k.

The paper's §2 argues Cosine similarity is NOT immune to the curse of
dimensionality — its practical advantage comes from real data's low
intrinsic dimensionality.  This ablation makes that quantitative for the
search system: block-pruning fraction of the exact kNN as a function of
(a) intrinsic dimension (number of angular clusters at fixed ambient dim),
(b) ambient dimension at fixed cluster count, and (c) k.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import ref
from repro.core.index import build_index
from repro.search import SearchEngine


def _data(n, d, n_centers, noise, rng):
    c = ref.normalize(rng.normal(size=(n_centers, d)))
    x = c[rng.integers(0, n_centers, n)] + noise * rng.normal(size=(n, d))
    return ref.normalize(x).astype(np.float32)


def _search(idx, q, k):
    # natural-order scan, no warm start: the ablation isolates the raw
    # bound's pruning power, not the engine's scheduling policies
    eng = SearchEngine(idx, backend="scan", warm_start=False,
                       best_first=False)
    return eng.search(q, k)


def run(n: int = 4096):
    rng = np.random.default_rng(0)
    rows = []
    # (a) intrinsic dimensionality sweep (ambient 64)
    for centers in (4, 16, 64, 4096):   # 4096 ~ fully uniform
        db = _data(n, 64, centers, 0.05, rng)
        q = jnp.asarray(db[rng.choice(n, 32, replace=False)])
        idx = build_index(jnp.asarray(db), n_pivots=16, block_size=64)
        _, _, st = _search(idx, q, 10)
        rows.append((f"dimensionality/centers{centers}/block_prune_frac",
                     float(st["block_prune_frac"]),
                     "intrinsic dim up => pruning down (paper §2)"))
    # (b) ambient dimension sweep (16 clusters).  Per-coordinate noise is
    # scaled by 1/sqrt(d) so the ANGULAR spread is dimension-independent —
    # otherwise the sweep silently raises intrinsic dimension too.
    for d in (8, 32, 128, 512):
        db = _data(n, d, 16, 0.4 / np.sqrt(d), rng)
        q = jnp.asarray(db[rng.choice(n, 32, replace=False)])
        idx = build_index(jnp.asarray(db), n_pivots=16, block_size=64)
        _, _, st = _search(idx, q, 10)
        rows.append((f"dimensionality/ambient{d}/block_prune_frac",
                     float(st["block_prune_frac"]),
                     "ambient dim ~irrelevant at fixed ANGULAR spread"))
    # (c) k sweep (16 clusters, d=64)
    db = _data(n, 64, 16, 0.05, rng)
    q = jnp.asarray(db[rng.choice(n, 32, replace=False)])
    idx = build_index(jnp.asarray(db), n_pivots=16, block_size=64)
    for k in (1, 10, 50):
        _, _, st = _search(idx, q, k)
        rows.append((f"dimensionality/k{k}/block_prune_frac",
                     float(st["block_prune_frac"]),
                     "larger k => lower tau => less pruning"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.4f},{note}")
