"""Reusable wall-clock timing core for the benchmark harnesses.

Every latency number this repo reports goes through :func:`measure`, which
fixes the methodology bug the old ad-hoc helpers shared: the *first* call
to a jitted function pays tracing + XLA compilation, so timing it (or
averaging it into the reps) measures the compiler, not the search.  Here
warmup and timed reps are strictly separated:

* ``warmup`` calls run first and are never timed — the first one is
  recorded as ``compile_s`` (trace + compile + run) so harnesses can
  report dispatch-cache behavior, the rest absorb allocator/frequency
  transients;
* each of the ``reps`` timed calls is individually bracketed with
  ``jax.block_until_ready`` on the call's outputs, so async dispatch
  cannot smear one rep's device work into the next rep's clock.

Per-rep times are kept (not just the mean): p50 is the number CI gates on
(robust to a single descheduled rep), p99 surfaces tail behavior — with
few reps it degrades to the max, which is the honest reading of "worst
rep observed".  Ratios of p50s on the same host are stable where absolute
microseconds are not; ``tools/check_bench_regression.py`` gates only the
ratios.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

__all__ = ["Timing", "measure"]


@dataclass(frozen=True)
class Timing:
    """Per-rep wall-clock samples from one :func:`measure` run (seconds)."""

    reps_s: tuple[float, ...]   # individually-blocked timed reps
    compile_s: float            # first warmup call: trace + compile + run

    def _pct(self, q: float) -> float:
        return float(np.percentile(np.asarray(self.reps_s), q))

    @property
    def p50_s(self) -> float:
        return self._pct(50.0)

    @property
    def p99_s(self) -> float:
        """99th percentile rep; with few reps this is the observed max."""
        return self._pct(99.0)

    @property
    def min_s(self) -> float:
        return float(min(self.reps_s))

    @property
    def mean_s(self) -> float:
        return float(np.mean(np.asarray(self.reps_s)))

    # microsecond views (what the benchmark rows report)
    @property
    def p50_us(self) -> float:
        return self.p50_s * 1e6

    @property
    def p99_us(self) -> float:
        return self.p99_s * 1e6

    @property
    def min_us(self) -> float:
        return self.min_s * 1e6

    @property
    def compile_us(self) -> float:
        return self.compile_s * 1e6


def measure(fn, *, warmup: int = 2, reps: int = 5) -> Timing:
    """Time ``fn()`` with warmup strictly separated from the timed reps.

    ``fn`` takes no arguments (close over them) and returns the values to
    block on — return everything the call produces so no device work
    escapes the clock.  ``warmup >= 1`` (the compile must happen outside
    the timed region); ``reps >= 1``.
    """
    if warmup < 1 or reps < 1:
        raise ValueError(f"measure needs warmup >= 1 and reps >= 1, got "
                         f"warmup={warmup} reps={reps}")
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    compile_s = time.perf_counter() - t0
    for _ in range(warmup - 1):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(reps):
        t1 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t1)
    return Timing(tuple(samples), compile_s)
