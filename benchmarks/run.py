"""Benchmark harness — one module per paper table/figure + system benches.

Prints ``name,value,derived`` CSV rows.  Mapping to the paper:

  bound_tightness      — §4.1, Figs. 1–4 (grid averages, max gap, ordering)
  numerical_stability  — §4.2, Fig. 5
  bound_runtime        — Table 2 (vectorized throughput analogue)
  pruning_power        — the paper's declared future work: bounds inside
                         actual index structures (VP-tree / LAESA / blocks)
  knn_scale            — end-to-end search timing on this host
  roofline             — §Roofline terms from the dry-run artifacts (only
                         emits rows if experiments/dryrun/ is populated)
"""
from __future__ import annotations

import sys
import traceback

import os
os.environ.setdefault("JAX_ENABLE_X64", "1")   # Table 2 runs in fp64 like the paper
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (bound_runtime, bound_tightness, dimensionality,
                        knn_scale, numerical_stability, pruning_power,
                        roofline)

MODULES = [
    ("bound_tightness", bound_tightness),
    ("numerical_stability", numerical_stability),
    ("bound_runtime", bound_runtime),
    ("pruning_power", pruning_power),
    ("knn_scale", knn_scale),
    ("dimensionality", dimensionality),
    ("roofline", roofline),
]


def main() -> None:
    failed = 0
    for name, mod in MODULES:
        try:
            for row_name, val, note in mod.run():
                print(f"{row_name},{val},{note}")
        except Exception as e:
            failed += 1
            print(f"{name}/ERROR,-1,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
