"""Benchmark harness — one module per paper table/figure + system benches.

Prints ``name,value,derived`` CSV rows.  Mapping to the paper:

  bound_tightness      — §4.1, Figs. 1–4 (grid averages, max gap, ordering)
  numerical_stability  — §4.2, Fig. 5
  bound_runtime        — Table 2 (vectorized throughput analogue)
  pruning_power        — the paper's declared future work: bounds inside
                         actual index structures (VP-tree / LAESA / blocks)
  knn_scale            — end-to-end search timing on this host
  latency              — wall-clock p50/p99 per backend x regime x batch
                         (the BENCH_latency.json grid; quick mode here)
  roofline             — §Roofline terms from the dry-run artifacts (only
                         emits rows if experiments/dryrun/ is populated)

A registered benchmark that raises fails the whole run: the error is
printed as an ``ERROR`` row AND a stderr traceback, and the exit code is
nonzero — a silently-skipped benchmark looks identical to a passing one
in collected CSV, so skipping is never an option.
"""
from __future__ import annotations

import sys
import traceback

import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")  # never stall on TPU autodetect
_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [_root, os.path.join(_root, "src")]   # runnable from anywhere

import jax

from benchmarks import (bound_runtime, bound_tightness, dimensionality,
                        knn_scale, latency, numerical_stability,
                        pruning_power, roofline)

#: (name, zero-arg callable returning (row_name, value, note) rows, x64).
#: The paper-table modules run in fp64 like the paper (Table 2, §4.1–4.2);
#: the system benches must run with x64 OFF — the Pallas kernel stores
#: int32 ids and global-x64 would promote index literals to int64 inside
#: the kernel.  latency runs its quick grid here (same rows as the CI
#: job; the full grid is ``python benchmarks/latency.py`` stand-alone —
#: never run it concurrently with the rest of this harness).
MODULES = [
    ("bound_tightness", bound_tightness.run, True),
    ("numerical_stability", numerical_stability.run, True),
    ("bound_runtime", bound_runtime.run, True),
    ("pruning_power", pruning_power.run, False),
    ("knn_scale", knn_scale.run, False),
    ("latency", lambda: latency.run(quick=True), False),
    ("dimensionality", dimensionality.run, True),
    ("roofline", roofline.run, False),
]


def main() -> None:
    failed = 0
    for name, run_rows, x64 in MODULES:
        jax.config.update("jax_enable_x64", x64)
        try:
            for row_name, val, note in run_rows():
                print(f"{row_name},{val},{note}")
        except Exception as e:
            failed += 1
            print(f"{name}/ERROR,-1,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        print(f"{failed} benchmark(s) raised — failing the run",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
