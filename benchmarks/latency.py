"""Wall-clock latency/QPS baseline — the measured-time gate.

Everything CI gated before this harness was a pruning *fraction*
(BENCH_pruning.json): a backend could get slower while "pruning improved".
This harness measures what the paper actually promises — that the Eq. 13
bound makes *exact search fast* — as p50/p99 wall-clock per backend ×
data regime × query batch size × k, through :mod:`benchmarks.timing`
(warmup-separated reps; the first call's compile time is never averaged
into a latency number).

Rows (all microseconds unless named otherwise):

* ``latency/<regime>/<backend>/m<m>/k<k>/p50_us`` / ``p99_us`` —
  informational absolutes (they move with the host; CI does not gate
  them);
* ``latency/<regime>/ratio/m<m>/k<k>/<a>_speedup_vs_<b>`` — p50 ratios
  (pruned/brute, engine/brute, engine/base).  These are what
  ``tools/check_bench_regression.py`` tolerance-bands: ratios of medians
  on the same host are stable where absolute microseconds flake;
* ``latency/<regime>/<backend>_matches_brute`` — exactness gates (1.0 =
  identical similarity profile to fp64 brute force), hard-failed by the
  regression gate exactly like the pruning rows;
* ``latency/online/...`` — the sustained-serving section: one scan
  engine absorbs interleaved insert/delete batches
  (:meth:`SearchEngine.online`) between query microbatches.
  ``sustained_qps`` and ``mutation_us`` are informational absolutes
  (host-dependent, like every ``*_us`` row); ``online_matches_brute``
  is a required hard gate — after every mutation step the search
  results must equal fp64 brute force over exactly the live corpus;
* ``latency/sharded_online/...`` — the same serve loop on a 4-shard
  sharded engine (deterministic cross-host placement, DESIGN.md §3.10),
  run in a child subprocess with its own virtual-device count, with a
  per-shard reoptimize at the midpoint.
  ``sharded_online_matches_brute`` is a required hard gate.

Backends measured: ``brute`` (the no-index floor), ``base`` (flat scan,
no warm start / best-first — the pre-engine pruned path), ``engine``
(scan with the full engine policy stack), ``tree`` (transitive Eq. 13
descent, scan leaves), ``kernel`` (fused Pallas kernel; interpret mode
off-TPU, so its absolute numbers on CPU measure the interpreter — its
*ratios* are still tracked for regressions).

``--quick`` keeps the full backend × regime × batch × k grid but shrinks
the corpus and rep count — this is what the CI ``latency`` job runs and
what the committed ``BENCH_latency.json`` baseline was produced with
(ratios stay comparable; a quick and a full run are not, and the gate
refuses to compare them).  ``--json PATH`` writes the machine-readable
payload.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __name__ == "__main__":       # runnable from anywhere, TPU probe pinned off
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

import jax.numpy as jnp
import numpy as np

from benchmarks.timing import measure
from repro.core import ref
from repro.core.index import build_index
from repro.search import SearchEngine

#: (batch sizes, k values) — one grid for quick and full runs, so the row
#: names line up and a host's quick baseline stays comparable over time
BATCH_SIZES = (8, 64)
K_VALUES = (10, 48)

#: engine variants measured per regime; "base" and "engine" share the scan
#: backend (the pair isolates what the engine policy stack buys)
VARIANTS = ("brute", "base", "engine", "tree", "kernel")


def make_regime(regime: str, n: int, d: int, seed: int = 0) -> np.ndarray:
    """The two data regimes the pruning bench established: ``clustered``
    (realistic neural-embedding case — pruning works) and ``uniform``
    (high-dim concentration — the bound's hard case)."""
    rng = np.random.default_rng(seed)
    if regime == "uniform":
        return ref.normalize(rng.normal(size=(n, d))).astype(np.float32)
    if regime == "clustered":
        c = ref.normalize(rng.normal(size=(8, d)))
        return ref.normalize(
            c[rng.integers(0, 8, n)] + 0.05 * rng.normal(size=(n, d))
        ).astype(np.float32)
    raise ValueError(f"unknown regime {regime!r}")


def build_variants(db: np.ndarray, *, block_size: int = 128) -> dict:
    """One shared index, five engine variants over it."""
    idx = build_index(jnp.asarray(db), n_pivots=16, block_size=block_size)
    return {
        "brute": SearchEngine(idx, backend="brute"),
        "base": SearchEngine(idx, backend="scan", warm_start=False,
                             best_first=False),
        "engine": SearchEngine(idx, backend="scan"),
        "tree": SearchEngine(idx, backend="tree", leaf_eval="scan"),
        "kernel": SearchEngine(idx, backend="kernel", bm=8),
    }


def _matches_brute(sims, db, q, k) -> float:
    """1.0 iff the similarity profile equals fp64 brute force."""
    sref, _ = ref.brute_force_knn(np.asarray(q), db, k)
    return float(np.allclose(np.asarray(sims), sref, atol=3e-5))


def run(*, quick: bool = False, regimes=("clustered", "uniform"),
        variants=VARIANTS, batch_sizes=BATCH_SIZES, k_values=K_VALUES,
        warmup: int = 2, reps: int | None = None, seed: int = 0):
    """Measure the grid; returns ``(name, value, note)`` rows."""
    n, d = (1536, 32) if quick else (4096, 64)
    reps = (3 if quick else 7) if reps is None else reps
    rng = np.random.default_rng(seed + 1)
    rows = []
    for regime in regimes:
        db = make_regime(regime, n, d, seed)
        engines = build_variants(db)
        engines = {v: engines[v] for v in variants}
        p50 = {}
        for m in batch_sizes:
            q = db[rng.choice(n, m, replace=False)]
            q = ref.normalize(
                q + 0.01 * rng.normal(size=q.shape)).astype(np.float32)
            qj = jnp.asarray(q)
            for k in k_values:
                for name, eng in engines.items():
                    # hot path only: sims/ids block the clock, the lazy
                    # stats scalars stay un-synced exactly as in serving
                    t = measure(lambda e=eng: e.search(qj, k)[:2],
                                warmup=warmup, reps=reps)
                    p50[name, m, k] = t.p50_s
                    tag = f"latency/{regime}/{name}/m{m}/k{k}"
                    rows.append((f"{tag}/p50_us", t.p50_us,
                                 f"reps={reps} warmup={warmup}"))
                    rows.append((f"{tag}/p99_us", t.p99_us,
                                 "max rep at small rep counts"))
                # gated ratios: >1 means the numerator path is faster
                rtag = f"latency/{regime}/ratio/m{m}/k{k}"
                ratios = (("pruned_speedup_vs_brute", "brute", "base"),
                          ("engine_speedup_vs_brute", "brute", "engine"),
                          ("engine_speedup_vs_base", "base", "engine"))
                for rname, slow, fast in ratios:
                    if slow in variants and fast in variants:
                        rows.append((f"{rtag}/{rname}",
                                     p50[slow, m, k] / p50[fast, m, k],
                                     f"p50({slow}) / p50({fast})"))
        # exactness: one gate per variant per regime, at the widest cell
        m, k = batch_sizes[-1], k_values[0]
        q = db[rng.choice(n, m, replace=False)]
        q = ref.normalize(
            q + 0.01 * rng.normal(size=q.shape)).astype(np.float32)
        for name, eng in engines.items():
            sims, _, _ = eng.search(jnp.asarray(q), k)
            rows.append((f"latency/{regime}/{name}_matches_brute",
                         _matches_brute(sims, db, q, k),
                         "exactness gate: must be 1.0"))
    rows.extend(run_online(quick=quick, seed=seed))
    rows.extend(run_online_sharded(quick=quick, seed=seed))
    return rows


def run_online(*, quick: bool = False, seed: int = 0):
    """Sustained serving under mutation: interleave insert/delete batches
    with query microbatches on one online scan engine (DESIGN.md §3.9).

    The timed region covers mutations + searches (the steady-state serve
    loop); the exactness audit — engine results vs fp64 brute force over
    exactly the rows live at that moment — runs after each step, outside
    the clock.  ``online_matches_brute`` is the min over all steps, so a
    single stale tombstone or missed insert anywhere in the run fails
    the 1.0 gate.
    """
    n, d = (1536, 32) if quick else (4096, 64)
    steps = 6 if quick else 12
    m, k, n_ins, n_del = 32, 10, 16, 4
    rng = np.random.default_rng(seed + 2)
    db = make_regime("clustered", n, d, seed)
    eng = SearchEngine.build(db, n_pivots=16, block_size=128,
                             backend="scan")
    h = eng.online(auto_reoptimize=False)
    live = {i: db[i] for i in range(n)}

    def draw_queries():
        base = np.stack([live[int(i)] for i in
                         rng.choice(sorted(live), m, replace=False)])
        return ref.normalize(
            base + 0.01 * rng.normal(size=base.shape)).astype(np.float32)

    # compile warmup — never timed, like benchmarks.timing does it
    np.asarray(eng.search(jnp.asarray(draw_queries()), k)[0])
    busy = mut_s = 0.0
    n_queries = 0
    exact = 1.0
    for _ in range(steps):
        new = rng.normal(size=(n_ins, d)).astype(np.float32)
        dead = [int(x) for x in
                rng.choice(sorted(live), size=n_del, replace=False)]
        qs = [draw_queries() for _ in range(2)]
        t0 = time.perf_counter()
        ids = h.insert(new)
        h.delete(dead)
        mut_s += time.perf_counter() - t0
        outs = [eng.search(jnp.asarray(q), k)[:2] for q in qs]
        for s_, i_ in outs:
            np.asarray(s_), np.asarray(i_)    # block: serving syncs here
        busy += time.perf_counter() - t0
        n_queries += len(qs) * m
        for i, r in zip(ids, new):
            live[i] = r
        for x in dead:
            del live[x]
        # untimed audit vs exactly the live corpus
        live_rows = np.stack([live[i] for i in sorted(live)])
        exact = min(exact,
                    _matches_brute(outs[-1][0], live_rows, qs[-1], k))
    return [
        ("latency/online/sustained_qps", n_queries / busy,
         f"{steps} steps x ({n_ins} ins + {n_del} del + {2 * m} queries); "
         f"informational"),
        ("latency/online/mutation_us", 1e6 * mut_s / (2 * steps),
         "mean per insert-or-delete call; informational"),
        ("latency/online/online_matches_brute", exact,
         "exactness gate vs live corpus after every step: must be 1.0"),
    ]


def run_online_sharded(*, quick: bool = False, seed: int = 0):
    """Sustained serving under mutation on a **sharded** engine
    (DESIGN.md §3.10): the deterministic-placement twin of
    :func:`run_online`, with a mid-run per-shard reoptimize.

    The bench process is pinned to one device (and may share a session
    with single-device engines), so the sharded run happens in a child
    subprocess with its own ``--xla_force_host_platform_device_count=4``
    — the same isolation tests/test_distributed.py uses.  The child
    emits its rows as one JSON line; a crashed child reports the
    ``sharded_online_matches_brute`` gate as 0.0 rather than silently
    dropping the row.
    """
    import subprocess
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    cmd = [sys.executable, os.path.abspath(__file__),
           "--sharded-online-child", "--seed", str(seed)]
    if quick:
        cmd.append("--quick")
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=900)
    if out.returncode != 0:
        return [("latency/sharded_online/sharded_online_matches_brute", 0.0,
                 f"exactness gate: child subprocess failed rc={out.returncode}"
                 f": {out.stderr.strip().splitlines()[-1] if out.stderr.strip() else 'no stderr'}")]
    return [tuple(r) for r in json.loads(out.stdout.splitlines()[-1])]


def _run_online_sharded_child(*, quick: bool, seed: int):
    """Child-process body for :func:`run_online_sharded` (4 virtual
    devices): interleave insert/delete batches with query microbatches on
    a sharded engine, reoptimize at the midpoint, audit against fp64
    brute force over exactly the live rows after every step."""
    import jax
    n, d = (1536, 32) if quick else (4096, 64)
    steps = 6 if quick else 12
    m, k, n_ins, n_del = 32, 10, 16, 4
    rng = np.random.default_rng(seed + 3)
    db = make_regime("clustered", n, d, seed)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    eng = SearchEngine.build(db, n_pivots=16, block_size=128, mesh=mesh)
    assert eng.backend_name == "sharded"
    h = eng.online(auto_reoptimize=False)
    live = {i: db[i] for i in range(n)}

    def draw_queries():
        base = np.stack([live[int(i)] for i in
                         rng.choice(sorted(live), m, replace=False)])
        return ref.normalize(
            base + 0.01 * rng.normal(size=base.shape)).astype(np.float32)

    np.asarray(eng.search(jnp.asarray(draw_queries()), k)[0])  # compile
    busy = mut_s = 0.0
    n_queries = 0
    exact = 1.0
    for step in range(steps):
        if step == steps // 2:
            # repack + re-replication: a rebuild event, outside the
            # steady-state clocks but inside the exactness audit
            h.reoptimize()
        new = rng.normal(size=(n_ins, d)).astype(np.float32)
        dead = [int(x) for x in
                rng.choice(sorted(live), size=n_del, replace=False)]
        qs = [draw_queries() for _ in range(2)]
        t0 = time.perf_counter()
        ids = h.insert(new)
        h.delete(dead)
        mut_s += time.perf_counter() - t0
        outs = [eng.search(jnp.asarray(q), k)[:2] for q in qs]
        for s_, i_ in outs:
            np.asarray(s_), np.asarray(i_)
        busy += time.perf_counter() - t0
        n_queries += len(qs) * m
        for i, r in zip(ids, new):
            live[i] = r
        for x in dead:
            del live[x]
        live_rows = np.stack([live[i] for i in sorted(live)])
        exact = min(exact,
                    _matches_brute(outs[-1][0], live_rows, qs[-1], k))
    return [
        ("latency/sharded_online/sustained_qps", n_queries / busy,
         f"{steps} steps x ({n_ins} ins + {n_del} del + {2 * m} queries), "
         f"{jax.device_count()} shards, mid-run reoptimize; informational"),
        ("latency/sharded_online/mutation_us", 1e6 * mut_s / (2 * steps),
         "mean per sharded insert-or-delete call; informational"),
        ("latency/sharded_online/sharded_online_matches_brute", exact,
         "exactness gate vs live corpus after every step: must be 1.0"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="wall-clock latency baseline (BENCH_latency.json)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpus + fewer reps, same grid (CI mode; "
                         "the committed baseline is a quick run)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write rows as JSON (BENCH_latency.json format)")
    ap.add_argument("--reps", type=int, default=None,
                    help="override timed reps per cell")
    # internal entry point spawned by run_online_sharded
    ap.add_argument("--sharded-online-child", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--seed", type=int, default=0, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.sharded_online_child:
        rows = _run_online_sharded_child(quick=args.quick, seed=args.seed)
        print(json.dumps([[n, float(v), t] for n, v, t in rows]))
        return 0
    rows = run(quick=args.quick, reps=args.reps)
    for name, val, note in rows:
        print(f"{name},{val:.4f},{note}")
    if args.json:
        payload = {
            "benchmark": "latency",
            "quick": args.quick,
            "metrics": [{"name": n, "value": round(float(v), 4), "note": t}
                        for n, v, t in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json} ({len(rows)} metrics)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
