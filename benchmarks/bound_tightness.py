"""Paper §4.1 / Figs. 1-4: bound tightness on the similarity grid.

Reproduces the paper's quantitative claims:
  * avg Euclidean bound 0.2447 vs avg Arccos bound 0.3121 (+27.5%) over the
    uniformly-sampled grid restricted to inputs where both bounds are
    non-negative,
  * max Euclid-vs-Arccos gap = 0.5 attained at a = b = 0.5 (Fig. 1c),
  * Fig. 3 ordering of all six bounds (checked exhaustively on the grid).
"""
from __future__ import annotations

import numpy as np

from repro.core import ref


def run(grid: int = 1001):
    g = np.linspace(-1.0, 1.0, grid)
    A, B = np.meshgrid(g, g)
    eu = ref.lb_euclid(A, B)
    ar = ref.lb_arccos(A, B)
    mu = ref.lb_mult(A, B)

    # §4.1 averages.  The paper's 0.3121 reproduces EXACTLY as the mean of
    # the Arccos bound over its own non-negative region; the companion
    # 0.2447 for the Euclidean bound does not reproduce under any protocol
    # we tried (own-region 0.3353; both-nonneg-region 0.3353/0.3855; [0,1]
    # grid variants; clipped means) — recorded as a non-reproducible detail.
    # The substantive pointwise claim (Arccos >= Euclid everywhere, so
    # pruning power is strictly better) holds exhaustively.
    avg_ar_own = float(ar[ar >= 0].mean())
    avg_eu_own = float(eu[eu >= 0].mean())
    both_nn = (eu >= 0) & (ar >= 0)
    avg_eu_b, avg_ar_b = float(eu[both_nn].mean()), float(ar[both_nn].mean())

    # Fig. 1a: Euclidean bound floor (paper: "can go down to -7")
    eu_min = float(eu.min())

    # Fig. 1c on the non-negative INPUT domain with bounds clamped to >= -1
    nn = (A >= 0) & (B >= 0)
    gap_nn = np.where(nn, np.maximum(ar, -1.0) - np.maximum(eu, -1.0), -np.inf)
    i = np.unravel_index(np.argmax(gap_nn), gap_nn.shape)

    # orderings: Fig. 3 chains (simplified-bound chain on the non-negative
    # domain, where Eq. 11 is valid — see tests/test_bounds.py)
    eps = 1e-12
    ord_global = bool((ref.lb_euclid_fast(A, B) <= eu + eps).all()
                      and (eu <= mu + eps).all()
                      and np.allclose(ar, mu, atol=1e-9))
    Ann, Bnn = np.meshgrid(np.linspace(0, 1, 401), np.linspace(0, 1, 401))
    ord_nn = bool(
        (ref.lb_mult_fast2(Ann, Bnn) <= ref.lb_mult_fast1(Ann, Bnn) + eps).all()
        and (ref.lb_mult_fast1(Ann, Bnn) <= ref.lb_mult(Ann, Bnn) + eps).all()
        and (ref.lb_euclid_fast(Ann, Bnn) <= ref.lb_mult_fast2(Ann, Bnn) + eps).all())

    return [
        ("tightness/avg_arccos_bound_own_region", avg_ar_own,
         "paper: 0.3121 — exact match"),
        ("tightness/avg_euclid_bound_own_region", avg_eu_own,
         "paper reports 0.2447; not reproducible (see comment)"),
        ("tightness/avg_euclid_both_nonneg", avg_eu_b, ""),
        ("tightness/avg_arccos_both_nonneg", avg_ar_b,
         f"pointwise arccos>=euclid everywhere; gap {avg_ar_b-avg_eu_b:.4f} on common region"),
        ("tightness/euclid_bound_min", eu_min, "paper Fig. 1a: -7 — match"),
        ("tightness/fig1c_max_gap_nonneg", float(gap_nn[i]), "paper: 0.5"),
        ("tightness/fig1c_argmax_a", float(A[i]), "paper: 0.5"),
        ("tightness/fig1c_argmax_b", float(B[i]), "paper: 0.5"),
        ("tightness/fig3_ordering_global", float(ord_global), "Eucl-LB<=Euclid<=Mult=Arccos"),
        ("tightness/fig3_ordering_simplified_nonneg", float(ord_nn),
         "Eucl-LB<=Mult-LB2<=Mult-LB1<=Mult on [0,1]^2"),
    ]


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.4f},{note}")
