"""repro-lint: AST static analysis for the repo's JAX/Pallas invariants.

Run ``python -m tools.lint`` from the repo root.  See docs/lint.md for
the rule table and the suppression/baseline contract.
"""
from tools.lint.core import (  # noqa: F401
    FileContext,
    Finding,
    Rule,
    all_rules,
    lint_file,
    lint_source,
    load_baseline,
    register,
    repo_root,
)
from tools.lint import rules as _rules  # noqa: F401  (registers R001-R008)
