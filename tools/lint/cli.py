"""The ``python -m tools.lint`` entry point.

One command runs both halves of the CI ``lint`` job:

* **repro-lint** — the AST rules in :mod:`tools.lint.rules`, stdlib-only
  (no jax import, so the gate is cheap enough to run first in CI);
* **ruff** — the pinned generic layer (unused imports, undefined names,
  mutable default args; config in pyproject.toml).  ruff is not baked
  into the dev container, so locally it is *skipped with a note* when
  the binary is absent; CI installs the pinned version and passes
  ``--require-ruff`` so absence fails there.

Exit status is non-zero iff any non-baselined repro-lint finding exists
(or ruff fails / is missing under ``--require-ruff``).
"""
from __future__ import annotations

import argparse
import json
import shutil
import subprocess
import sys
from pathlib import Path

from tools.lint.core import (
    Finding,
    all_rules,
    lint_file,
    load_baseline,
    repo_root,
)

#: directories scanned by default (repo-relative)
DEFAULT_PATHS = ("src", "tools", "benchmarks", "tests")

#: never scanned: the fixture corpus exists to violate the rules
EXCLUDED = ("tools/lint/selftest",)

BASELINE = "tools/lint/baseline.json"


def iter_python_files(root: Path, paths: list[str]) -> list[Path]:
    """Python files under ``paths`` (repo-relative), fixture corpus
    excluded, sorted for deterministic output."""
    out: list[Path] = []
    for p in paths:
        base = root / p
        if base.is_file() and base.suffix == ".py":
            candidates = [base]
        else:
            candidates = sorted(base.rglob("*.py"))
        for f in candidates:
            try:
                rel = f.relative_to(root).as_posix()
            except ValueError:      # outside the repo (scratch/seeded files)
                rel = f.as_posix()
            if any(rel == e or rel.startswith(e + "/") for e in EXCLUDED):
                continue
            out.append(f)
    return out


def run_repro_lint(root: Path, paths: list[str]) -> list[Finding]:
    rules = all_rules()
    findings: list[Finding] = []
    for f in iter_python_files(root, paths):
        findings.extend(lint_file(f, root=root, rules=rules).findings)
    findings.sort(key=lambda x: (x.path, x.line, x.col, x.rule))
    return findings


def run_ruff(root: Path, paths: list[str], require: bool) -> tuple[int, str]:
    """Return (exit_code, note).  Exit 0 with a note when ruff is absent
    and not required — the container does not ship it; CI does."""
    exe = shutil.which("ruff")
    if exe is None:
        if require:
            return 1, "ruff: REQUIRED but not installed (CI pins ruff==0.8.4)"
        return 0, "ruff: not installed, skipped (CI runs it; " \
                  "pass --require-ruff to fail instead)"
    proc = subprocess.run(  # repro-lint: disable=R003  (ruff never imports jax)
        [exe, "check", *paths], cwd=root,
        capture_output=True, text=True)
    note = proc.stdout.strip() or proc.stderr.strip() or "ruff: clean"
    return proc.returncode, note


def write_baseline(root: Path, findings: list[Finding]) -> None:
    payload = {
        "_comment": "Grandfathered repro-lint findings (path:line:rule). "
                    "Shipped empty; see docs/lint.md before adding to it.",
        "findings": sorted(f.key for f in findings),
    }
    (root / BASELINE).write_text(json.dumps(payload, indent=2) + "\n")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="repro-lint (AST invariants) + ruff, one gate.")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to scan (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    ap.add_argument("--baseline", default=BASELINE,
                    help="baseline file of grandfathered findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "(burn-down tool; do not ship a non-empty one "
                         "without a docs/lint.md entry)")
    ap.add_argument("--no-ruff", action="store_true",
                    help="repro-lint only")
    ap.add_argument("--require-ruff", action="store_true",
                    help="fail (rather than skip) when ruff is missing — "
                         "CI sets this")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    root = repo_root()
    paths = list(args.paths) if args.paths else list(DEFAULT_PATHS)

    if args.list_rules:
        for r in all_rules():
            print(f"{r.id}  {r.title}")
            print(f"      provenance: {r.provenance}")
        return 0

    findings = run_repro_lint(root, paths)

    if args.write_baseline:
        write_baseline(root, findings)
        print(f"baseline written: {len(findings)} finding(s) -> "
              f"{args.baseline}")
        return 0

    baseline = load_baseline(root / args.baseline)
    fresh = [f for f in findings if f.key not in baseline]
    stale = baseline - {f.key for f in findings}

    ruff_rc, ruff_note = (0, "ruff: skipped (--no-ruff)") if args.no_ruff \
        else run_ruff(root, paths, args.require_ruff)

    if args.as_json:
        print(json.dumps({
            "findings": [f.as_dict() for f in fresh],
            "baselined": sorted(baseline & {f.key for f in findings}),
            "stale_baseline": sorted(stale),
            "ruff": {"exit": ruff_rc, "note": ruff_note},
        }, indent=2))
    else:
        for f in fresh:
            print(f)
        if stale:
            print(f"note: {len(stale)} baseline entr"
                  f"{'y is' if len(stale) == 1 else 'ies are'} stale "
                  f"(fixed or moved) — regenerate with --write-baseline",
                  file=sys.stderr)
        print(ruff_note, file=sys.stderr)
        n_files = len(iter_python_files(root, paths))
        print(f"repro-lint: {len(fresh)} finding(s) in {n_files} files "
              f"({len(baseline)} baselined)", file=sys.stderr)

    return 1 if (fresh or ruff_rc) else 0


if __name__ == "__main__":
    raise SystemExit(main())
