"""repro-lint core: single-parse AST analysis with a rule registry.

The framework is deliberately tiny and stdlib-only (no jax import — the
CI ``lint`` job runs before anything heavier installs):

* each file is parsed ONCE into a :class:`FileContext` that owns the
  shared analyses every rule needs (import-alias resolution, a parent
  map, the traced-function set for jit-body rules);
* rules register with :func:`register` and declare ``visit_<NodeType>``
  methods; one ``ast.walk`` dispatches every node to every applicable
  rule — O(nodes x matching-rules), not O(nodes x rules x passes);
* findings are suppressible per line with ``# repro-lint: disable=R001``
  (comma-separate several ids) and grandfatherable through a committed
  JSON baseline (:func:`load_baseline`; shipped empty — see
  docs/lint.md for the burn-down contract).

Rules live in :mod:`tools.lint.rules`; the CLI in :mod:`tools.lint.cli`.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path

__all__ = [
    "Finding", "FileContext", "Rule", "register", "all_rules",
    "lint_file", "lint_source", "load_baseline", "repo_root",
]

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Z0-9,\s]+)")

#: callables/attribute roots treated as engine-like mutable state holders
#: by the retrace-hazard rule (R008)
ENGINE_NAMES = frozenset({"self", "eng", "engine"})


def repo_root() -> Path:
    """The repository root (two levels above this package)."""
    return Path(__file__).resolve().parents[2]


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str            # repo-relative posix path (or the virtual path)
    line: int
    col: int
    message: str

    @property
    def key(self) -> str:
        """Stable identity used by the baseline: ``path:line:rule``."""
        return f"{self.path}:{self.line}:{self.rule}"

    def as_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


class Rule:
    """Base class: subclass, set ``id``/``title``/``provenance``, register.

    ``visit_<NodeType>(node, ctx)`` methods receive every matching AST
    node of the (single) walk plus the shared :class:`FileContext`.
    ``begin_file`` / ``end_file`` bracket the walk.  ``applies`` gates the
    rule per file (path-scoped rules override it) — a rule that does not
    apply costs nothing during the walk.
    """

    id: str = "R000"
    title: str = ""
    provenance: str = ""

    def applies(self, ctx: "FileContext") -> bool:
        return True

    def begin_file(self, ctx: "FileContext") -> None:
        pass

    def end_file(self, ctx: "FileContext") -> None:
        pass


_REGISTRY: list[type[Rule]] = []


def register(cls: type[Rule]) -> type[Rule]:
    """Class decorator: add a :class:`Rule` subclass to the registry."""
    assert cls.id not in {r.id for r in _REGISTRY}, f"duplicate rule {cls.id}"
    _REGISTRY.append(cls)
    return cls


def all_rules() -> list[Rule]:
    """Fresh instances of every registered rule, id-sorted."""
    return [cls() for cls in sorted(_REGISTRY, key=lambda c: c.id)]


# ---------------------------------------------------------------------------
# per-file context: shared analyses, computed lazily, parsed exactly once
# ---------------------------------------------------------------------------

_SCOPE_TYPES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


@dataclass
class FileContext:
    """Everything the rules share about one parsed file."""

    path: str                     # repo-relative posix path used in findings
    source: str
    tree: ast.Module
    findings: list[Finding] = field(default_factory=list)
    suppressed: list[Finding] = field(default_factory=list)
    _parents: dict | None = None
    _aliases: dict | None = None
    _traced: set | None = None
    _suppressions: dict | None = None

    # ------------------------------------------------------------ reporting
    def report(self, rule: Rule, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        f = Finding(rule.id, self.path, line, col, message)
        if rule.id in self.suppressions.get(line, set()):
            self.suppressed.append(f)
        else:
            self.findings.append(f)

    @property
    def suppressions(self) -> dict[int, set[str]]:
        """``{lineno: {rule ids}}`` from ``# repro-lint: disable=`` comments."""
        if self._suppressions is None:
            sup: dict[int, set[str]] = {}
            for i, text in enumerate(self.source.splitlines(), 1):
                m = _SUPPRESS_RE.search(text)
                if m:
                    sup[i] = {r.strip() for r in m.group(1).split(",")
                              if r.strip()}
            self._suppressions = sup
        return self._suppressions

    # ----------------------------------------------------- shared analyses
    @property
    def parents(self) -> dict:
        """``{child node: parent node}`` over the whole tree."""
        if self._parents is None:
            p = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    p[child] = node
            self._parents = p
        return self._parents

    @property
    def aliases(self) -> dict[str, str]:
        """Import alias map: local name -> fully dotted module/attr path."""
        if self._aliases is None:
            al: dict[str, str] = {}
            for node in ast.walk(self.tree):
                if isinstance(node, ast.Import):
                    for a in node.names:
                        al[a.asname or a.name.split(".")[0]] = (
                            a.name if a.asname else a.name.split(".")[0])
                elif isinstance(node, ast.ImportFrom) and node.module \
                        and node.level == 0:
                    for a in node.names:
                        if a.name != "*":
                            al[a.asname or a.name] = (
                                f"{node.module}.{a.name}")
            self._aliases = al
        return self._aliases

    def full_name(self, node: ast.AST) -> str | None:
        """Dotted path of a Name/Attribute chain with the root alias
        expanded (``pl.BlockSpec`` -> ``jax.experimental.pallas.BlockSpec``);
        None for anything that is not a pure attribute chain."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.aliases.get(node.id, node.id)
        return ".".join([root] + parts[::-1])

    # --------------------------------------------------- traced-code model
    def _is_jit_expr(self, e: ast.AST) -> bool:
        """Is ``e`` a jit transform: ``jax.jit``, ``jax.jit(...)``, or
        ``functools.partial(jax.jit, ...)``?"""
        if self.full_name(e) == "jax.jit":
            return True
        if isinstance(e, ast.Call):
            fn = self.full_name(e.func)
            if fn == "jax.jit":
                return True
            if fn == "functools.partial" and e.args \
                    and self.full_name(e.args[0]) == "jax.jit":
                return True
        return False

    @property
    def traced_functions(self) -> set:
        """Function/lambda nodes whose bodies run under a jax trace: jit
        roots (decorated, or passed to ``jax.jit(...)``) and Pallas kernel
        functions (first argument of ``pl.pallas_call``).  Code lexically
        nested inside one of these is traced too — use :meth:`in_traced`.
        """
        if self._traced is None:
            roots: set = set()
            wanted_names: set[str] = set()
            for node in ast.walk(self.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if any(self._is_jit_expr(d) for d in node.decorator_list):
                        roots.add(node)
                elif isinstance(node, ast.Call):
                    fn = self.full_name(node.func)
                    if fn == "jax.jit" and node.args:
                        tgt = node.args[0]
                        if isinstance(tgt, ast.Lambda):
                            roots.add(tgt)
                        elif isinstance(tgt, ast.Name):
                            wanted_names.add(tgt.id)
                    elif fn == "jax.experimental.pallas.pallas_call" \
                            and node.args and isinstance(node.args[0],
                                                         ast.Name):
                        wanted_names.add(node.args[0].id)
            if wanted_names:
                for node in ast.walk(self.tree):
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)) \
                            and node.name in wanted_names:
                        roots.add(node)
            self._traced = roots
        return self._traced

    def in_traced(self, node: ast.AST) -> bool:
        """True when ``node`` sits lexically inside a traced function."""
        cur = node
        while cur is not None:
            if cur in self.traced_functions:
                return True
            cur = self.parents.get(cur)
        return False

    def enclosing_function(self, node: ast.AST):
        """Nearest enclosing FunctionDef/AsyncFunctionDef, or None."""
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None

    def enclosing_scope_names(self, node: ast.AST) -> list[str]:
        """Names of every enclosing function/class, innermost first."""
        names = []
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                names.append(cur.name)
            cur = self.parents.get(cur)
        return names


# ---------------------------------------------------------------------------
# the single-walk dispatcher
# ---------------------------------------------------------------------------

def lint_source(source: str, path: str,
                rules: list[Rule] | None = None) -> FileContext:
    """Parse ``source`` once and run every applicable rule over one walk.

    ``path`` is the repo-relative posix path used both in findings and by
    path-scoped rules' ``applies`` — selftest fixtures pass a *virtual*
    path here to exercise those rules.
    """
    rules = all_rules() if rules is None else rules
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        ctx = FileContext(path, source, ast.Module(body=[], type_ignores=[]))
        ctx.findings.append(Finding(
            "E000", path, e.lineno or 1, (e.offset or 1) - 1,
            f"syntax error: {e.msg}"))
        return ctx
    ctx = FileContext(path, source, tree)
    active = [r for r in rules if r.applies(ctx)]
    dispatch: dict[str, list] = {}
    for rule in active:
        rule.begin_file(ctx)
        for name in dir(rule):
            if name.startswith("visit_"):
                dispatch.setdefault(name[6:], []).append(getattr(rule, name))
    if dispatch:
        for node in ast.walk(tree):
            for handler in dispatch.get(type(node).__name__, ()):
                handler(node, ctx)
    for rule in active:
        rule.end_file(ctx)
    ctx.findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return ctx


def lint_file(file_path: Path, root: Path | None = None,
              virtual_path: str | None = None,
              rules: list[Rule] | None = None) -> FileContext:
    """Lint one file; findings carry its repo-relative (or virtual) path."""
    root = root or repo_root()
    if virtual_path is None:
        try:
            virtual_path = file_path.resolve().relative_to(root).as_posix()
        except ValueError:
            virtual_path = file_path.as_posix()
    return lint_source(file_path.read_text(), virtual_path, rules)


def load_baseline(path: Path) -> set[str]:
    """Grandfathered finding keys (``path:line:rule``) from the committed
    baseline.  Shipped empty; regenerate deliberately with
    ``python -m tools.lint --write-baseline`` only while burning down."""
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("findings", []))
