"""The repo-specific rules (R001–R008).

Each rule encodes an invariant that was learned by debugging and until
now lived only in DESIGN.md prose — the docstrings cite where.  All
checks are pure AST (no jax import): they catch the *shape* of each
hazard, and the handful of sanctioned escape hatches either live in
whitelisted locations or carry an explicit
``# repro-lint: disable=RXXX`` comment at the call site, which is the
point — the exception becomes reviewable instead of ambient.
"""
from __future__ import annotations

import ast

from tools.lint.core import ENGINE_NAMES, FileContext, Rule, register

# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

_SUBPROCESS_SPAWNS = frozenset({
    "subprocess.run", "subprocess.Popen", "subprocess.call",
    "subprocess.check_call", "subprocess.check_output",
})

_COLLECTIVES = frozenset({
    "psum", "pmean", "pmax", "pmin", "psum_scatter", "all_gather",
    "all_to_all", "ppermute", "pshuffle", "pbroadcast", "axis_index",
})

_REDUCTION_METHODS = frozenset({
    "sum", "max", "min", "mean", "prod", "all", "any", "argmax", "argmin",
    "astype", "reshape", "squeeze", "item",
})


def _contains_string(node: ast.AST, text: str) -> bool:
    return any(isinstance(n, ast.Constant) and n.value == text
               for n in ast.walk(node))


# --------------------------------------------------------------------------
# R001 — the TopkRewriter breaker
# --------------------------------------------------------------------------

@register
class TopkSliceRule(Rule):
    """``lax.top_k(...)[0]`` immediately sliced again breaks XLA's fast TopK.

    Provenance: PR 6.  jax lowers ``top_k`` as sort+slice and XLA's
    TopkRewriter only recognizes slices starting at column 0 — composing
    a trailing-column slice (``[:, -1]``) folds into a ``[k-1:k]`` slice,
    the pattern dies, and the line silently runs as a full O(n log n)
    sort (measured ~812µs vs ~80µs on [64, 128] — a 10x latency loss that
    shipped unnoticed until the wall-clock gate landed).  The sanctioned
    escape hatch is ``repro.kernels.ref.kth_value``, whose
    ``optimization_barrier`` pins the intact [m, k] values so the rewrite
    fires; route through it, or barrier explicitly and suppress.
    """

    id = "R001"
    title = "top_k(...)[0] sliced again (TopkRewriter breaker)"
    provenance = "PR 6; kernels/ref.py:kth_value docstring"

    def visit_Subscript(self, node: ast.Subscript, ctx: FileContext) -> None:
        inner = node.value
        if not (isinstance(inner, ast.Subscript)
                and isinstance(inner.slice, ast.Constant)
                and inner.slice.value == 0
                and isinstance(inner.value, ast.Call)):
            return
        if ctx.full_name(inner.value.func) != "jax.lax.top_k":
            return
        if ctx.path == "src/repro/kernels/ref.py":
            fn = ctx.enclosing_function(node)
            if fn is not None and fn.name == "kth_value":
                return      # the one sanctioned, barrier-guarded site
        ctx.report(self, node,
                   "subscript on lax.top_k(...)[0] folds into the sort "
                   "lowering and breaks XLA's TopkRewriter (silent full "
                   "sort, ~10x; PR 6) — route through "
                   "repro.kernels.ref.kth_value")


# --------------------------------------------------------------------------
# R002 — post-0.4.37 jax APIs must stay behind repro.dist.compat
# --------------------------------------------------------------------------

@register
class CompatOnlyApiRule(Rule):
    """Version-sensitive jax APIs are reachable only through dist/compat.py.

    Provenance: ROADMAP "Seed-era note" and dist/compat.py.  The container
    ships jax 0.4.37: ``jax.shard_map`` (and its ``check_vma`` signature)
    does not exist, ``optimization_barrier`` has no grad rule, and
    ``make_array_from_process_local_data``'s signature is in flux.  Every
    call site goes through :mod:`repro.dist.compat` so a jax bump (or
    downgrade) is a one-file fix; a direct use works on the author's jax
    and breaks on the next — PR 1 restored a whole package that died this
    way.
    """

    id = "R002"
    title = "version-shimmed jax API used outside dist/compat.py"
    provenance = "ROADMAP seed-era note; PR 1; PR 5 (compat helpers)"

    _BANNED = (
        "jax.shard_map",
        "jax.experimental.shard_map",
        "jax.make_array_from_process_local_data",
        "jax.lax.optimization_barrier",
    )

    def applies(self, ctx: FileContext) -> bool:
        return ctx.path != "src/repro/dist/compat.py"

    def _check_name(self, node: ast.AST, name: str | None,
                    ctx: FileContext) -> None:
        if name and any(name == b or name.startswith(b + ".")
                        for b in self._BANNED):
            ctx.report(self, node,
                       f"{name} is version-shimmed — import it from "
                       f"repro.dist.compat (jax 0.4.37 contract, ROADMAP "
                       f"seed-era note)")

    def visit_Attribute(self, node: ast.Attribute, ctx: FileContext) -> None:
        parent = ctx.parents.get(node)
        if isinstance(parent, ast.Attribute) and parent.value is node:
            return        # inner link of a longer chain: outer node reports
        self._check_name(node, ctx.full_name(node), ctx)

    def visit_Import(self, node: ast.Import, ctx: FileContext) -> None:
        for a in node.names:
            self._check_name(node, a.name, ctx)

    def visit_ImportFrom(self, node: ast.ImportFrom, ctx: FileContext) -> None:
        if node.level or not node.module:
            return
        for a in node.names:
            self._check_name(node, f"{node.module}.{a.name}", ctx)


# --------------------------------------------------------------------------
# R003 — subprocess spawns must pin JAX_PLATFORMS
# --------------------------------------------------------------------------

@register
class SubprocessPlatformPinRule(Rule):
    """Python subprocesses must pin ``JAX_PLATFORMS`` in their env.

    Provenance: ROADMAP "Seed-era note"; PR 6 satellite.  The container
    installs a TPU plugin with no TPU attached: a spawned python that
    inherits an unset ``JAX_PLATFORMS`` stalls for *minutes* in
    GCP-metadata retries during backend autodetection before falling back
    to CPU — every smoke, bench child and test subprocess pins it.  The
    check is lexical: the enclosing function (or module, for top-level
    spawns) must mention the literal ``"JAX_PLATFORMS"`` somewhere; a
    spawn whose env is assembled elsewhere should say so with a
    suppression comment.
    """

    id = "R003"
    title = "subprocess spawn without a JAX_PLATFORMS pin in scope"
    provenance = "ROADMAP seed-era note; PR 6 (pinned every tool spawn)"

    def visit_Call(self, node: ast.Call, ctx: FileContext) -> None:
        if ctx.full_name(node.func) not in _SUBPROCESS_SPAWNS:
            return
        scope = ctx.enclosing_function(node) or ctx.tree
        if _contains_string(scope, "JAX_PLATFORMS"):
            return
        ctx.report(self, node,
                   "subprocess spawn with no JAX_PLATFORMS pin in the "
                   "enclosing scope — an inherited unset value stalls "
                   "minutes in TPU-plugin autodetection (ROADMAP "
                   "seed-era note)")


# --------------------------------------------------------------------------
# R004 — host syncs inside traced bodies
# --------------------------------------------------------------------------

@register
class HostSyncInJitRule(Rule):
    """No host-synchronizing calls inside jit-traced bodies.

    Provenance: DESIGN.md §3.1/§3.3 (raw stats stay jnp scalars so lookup
    can run inside a decode jit) and the PR 6 zero-retrace contract.
    ``.item()`` / ``np.asarray`` / ``float(array_expr)`` inside a traced
    body either crashes on tracers (when the value is data-dependent) or
    silently constant-folds trace-time state into the executable — the
    stale-capture variant of the retrace hazard R008 guards.  Host
    conversion belongs in the engine/caller layer, outside the jitted
    callee.  Heuristic: ``float()``/``int()``/``bool()`` are flagged only
    when their argument visibly involves jnp/jax or an array-reduction
    method call; static shape math (``int(x.shape[0])``) passes.
    """

    id = "R004"
    title = "host-sync call inside a jit-traced body"
    provenance = "DESIGN.md §3.3; PR 6 retrace-free hot path"

    _DIRECT = frozenset({"numpy.asarray", "numpy.array", "jax.device_get"})

    def _arrayish(self, node: ast.AST, ctx: FileContext) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Name) \
                    and ctx.aliases.get(n.id, "").split(".")[0] == "jax":
                return True
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                    and n.func.attr in _REDUCTION_METHODS:
                return True
        return False

    def visit_Call(self, node: ast.Call, ctx: FileContext) -> None:
        if not ctx.in_traced(node):
            return
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
                and not node.args:
            ctx.report(self, node,
                       ".item() synchronizes the host inside a traced "
                       "body (DESIGN.md §3.3) — return the array and "
                       "convert outside the jit")
            return
        name = ctx.full_name(node.func)
        if name in self._DIRECT:
            ctx.report(self, node,
                       f"{name} materializes a host value inside a traced "
                       f"body — keep device values jnp until after "
                       f"dispatch (DESIGN.md §3.3)")
            return
        if isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int", "bool") \
                and len(node.args) == 1 and not node.keywords \
                and self._arrayish(node.args[0], ctx):
            ctx.report(self, node,
                       f"{node.func.id}() on an array expression inside a "
                       f"traced body forces a host sync (or crashes on "
                       f"tracers) — keep it a jnp scalar (DESIGN.md §3.3)")


# --------------------------------------------------------------------------
# R005 — the mutation surface is collective-free
# --------------------------------------------------------------------------

@register
class MutationCollectiveRule(Rule):
    """DESIGN.md §3.10: the only collective in the mutation surface is the
    id-mirror re-replication.

    Provenance: PR 9 / DESIGN.md §3.10.  Sharded online mutation scales
    because placement is a pure function of replicated host state — every
    process decides identically with ZERO placement collectives, and the
    device applies are shard-local scatters.  The one exception is
    ``replicated_row_ids`` (the host mirror rebuild at handle init and
    after reoptimize, never per-mutation).  A collective that sneaks into
    an insert/delete path turns every mutation into a cross-host
    synchronization point and silently serializes the fleet.

    Scope: all of ``core/online.py``, plus the mutation surface of
    ``core/distributed.py`` (``ShardedMutationOps`` /
    ``make_sharded_mutation``); ``replicated_row_ids`` is the whitelist.
    The search-side collectives in the same file (the §3.6/§3.7 merges)
    are out of scope by design.
    """

    id = "R005"
    title = "collective primitive in the online-mutation surface"
    provenance = "DESIGN.md §3.10; PR 9"

    _FILES = ("src/repro/core/online.py", "src/repro/core/distributed.py")
    _SURFACE = {"ShardedMutationOps", "make_sharded_mutation"}
    _WHITELIST = {"replicated_row_ids"}

    def applies(self, ctx: FileContext) -> bool:
        return ctx.path in self._FILES

    def visit_Call(self, node: ast.Call, ctx: FileContext) -> None:
        name = ctx.full_name(node.func)
        if name is None or name.split(".")[-1] not in _COLLECTIVES:
            return
        if name.split(".")[0] not in ("jax", "jax.lax"):
            return
        scopes = ctx.enclosing_scope_names(node)
        if any(s in self._WHITELIST for s in scopes):
            return
        if ctx.path.endswith("distributed.py") \
                and not any(s in self._SURFACE for s in scopes):
            return      # search-side merge collectives: out of scope
        ctx.report(self, node,
                   f"collective {name.split('.')[-1]} in the mutation "
                   f"surface — DESIGN.md §3.10 allows exactly one "
                   f"(replicated_row_ids' id-mirror re-replication); "
                   f"placement must stay a pure function of replicated "
                   f"host state")


# --------------------------------------------------------------------------
# R006 — fp64 is a build/oracle dtype, never a device-path dtype
# --------------------------------------------------------------------------

@register
class DevicePathFloat64Rule(Rule):
    """No float64 / x64 mode in device-path modules.

    Provenance: DESIGN.md §3.8 (fp64 at build, fp32 stored) and the PR 6
    x64-scoping fix: enabling global x64 broke the Pallas int32 id stores
    and pruning_power/latency stopped running at all.  fp64 belongs in
    build/oracle code (``core/pivots.py``, ``core/ref.py``, the
    ``core/online.py`` host paths); the kernels and backend inner loops
    store fp32 and accumulate f32 — the slack constants
    (``JOINT_SLACK``, ``margin``) are budgeted for exactly that, so a
    stray fp64 upcast in the device path buys no correctness and costs
    2x memory traffic plus an x64-mode footgun.
    """

    id = "R006"
    title = "float64 / enable_x64 in a device-path module"
    provenance = "DESIGN.md §3.8 dtype discipline; PR 6 x64-scoping fix"

    def applies(self, ctx: FileContext) -> bool:
        return (ctx.path.startswith("src/repro/kernels/")
                or ctx.path == "src/repro/search/backends.py")

    def visit_Attribute(self, node: ast.Attribute, ctx: FileContext) -> None:
        name = ctx.full_name(node)
        if name in ("numpy.float64", "jax.numpy.float64"):
            ctx.report(self, node,
                       f"{name} in a device-path module — fp64 is a "
                       f"build/oracle dtype (DESIGN.md §3.8); store fp32 "
                       f"and budget the slack constants")

    def visit_Constant(self, node: ast.Constant, ctx: FileContext) -> None:
        if node.value == "float64":
            ctx.report(self, node,
                       "'float64' dtype string in a device-path module "
                       "(DESIGN.md §3.8 fp64-at-build/fp32-at-store)")

    def visit_Call(self, node: ast.Call, ctx: FileContext) -> None:
        name = ctx.full_name(node.func)
        if name == "jax.config.update" and node.args \
                and isinstance(node.args[0], ast.Constant) \
                and node.args[0].value == "jax_enable_x64":
            ctx.report(self, node,
                       "jax_enable_x64 toggled in a device-path module — "
                       "global x64 broke the Pallas int32 id stores "
                       "(PR 6); scope x64 to host/oracle code")

    def visit_Name(self, node: ast.Name, ctx: FileContext) -> None:
        if node.id == "enable_x64" or "enable_x64" in ctx.aliases.get(
                node.id, ""):
            ctx.report(self, node,
                       "enable_x64 in a device-path module (PR 6 "
                       "x64-scoping fix)")


# --------------------------------------------------------------------------
# R007 — pallas_call structural checks
# --------------------------------------------------------------------------

@register
class PallasCallStructureRule(Rule):
    """BlockSpec index_map arity must match the grid (+ scalar prefetch),
    and kernel ``*_ref`` operands must actually be read.

    Provenance: DESIGN.md §3.3/§3.9 and the PR 8 ``row_valid`` operand.
    Pallas reports an arity mismatch between an ``index_map`` lambda and
    the grid rank (plus ``num_scalar_prefetch`` leading refs) only deep
    inside tracing, long after the edit that caused it; and an operand a
    kernel accepts but never reads is how the §3.9 validity contract
    silently rots — the PR 8 kernel grew a ``row_valid`` [N, 1] operand
    precisely so tombstones mask per row, and a refactor that drops the
    read would still typecheck and still pass prefix-validity tests.
    Both checks are static here.  Grid rank is resolved from a literal
    ``grid=`` tuple (directly or via a single local assignment); sites
    with dynamic grids are skipped, not guessed.
    """

    id = "R007"
    title = "pallas_call index_map arity / unread kernel operand"
    provenance = "DESIGN.md §3.9; PR 8 row_valid operand; PR 7 cap operand"

    def visit_FunctionDef(self, node: ast.FunctionDef,
                          ctx: FileContext) -> None:
        self._check_unread_refs(node, ctx)
        self._check_index_maps(node, ctx)

    # ---- unread *_ref kernel operands
    def _check_unread_refs(self, node: ast.FunctionDef,
                           ctx: FileContext) -> None:
        args = node.args
        ref_params = [a for a in (args.posonlyargs + args.args
                                  + args.kwonlyargs)
                      if a.arg.endswith("_ref")]
        if not ref_params:
            return
        used = {n.id for stmt in node.body for n in ast.walk(stmt)
                if isinstance(n, ast.Name)}
        for a in ref_params:
            if a.arg not in used:
                ctx.report(self, a,
                           f"kernel operand {a.arg!r} is accepted but "
                           f"never read — an unread validity/bound "
                           f"operand silently voids the §3.9 masking "
                           f"contract (PR 8 row_valid)")

    # ---- index_map arity vs grid rank (+ scalar prefetch)
    def _grid_rank_and_prefetch(self, fn: ast.FunctionDef,
                                ctx: FileContext):
        rank = None
        prefetch = 0
        grid_names: dict[str, int] = {}
        for n in ast.walk(fn):
            if isinstance(n, ast.Assign) and isinstance(n.value, ast.Tuple):
                for t in n.targets:
                    if isinstance(t, ast.Name):
                        grid_names[t.id] = len(n.value.elts)
        for n in ast.walk(fn):
            if not isinstance(n, ast.Call):
                continue
            name = ctx.full_name(n.func) or ""
            is_pallas = name.endswith(".pallas_call")
            is_gridspec = name.endswith("GridSpec")
            if not (is_pallas or is_gridspec):
                continue
            for kw in n.keywords:
                if kw.arg == "grid":
                    if isinstance(kw.value, ast.Tuple):
                        rank = len(kw.value.elts)
                    elif isinstance(kw.value, ast.Name):
                        rank = grid_names.get(kw.value.id, rank)
                elif kw.arg == "num_scalar_prefetch" \
                        and isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, int):
                    prefetch = kw.value.value
        return rank, prefetch

    def _check_index_maps(self, fn: ast.FunctionDef,
                          ctx: FileContext) -> None:
        has_pallas = any(
            isinstance(n, ast.Call)
            and (ctx.full_name(n.func) or "").endswith(".pallas_call")
            for n in ast.walk(fn))
        if not has_pallas:
            return
        rank, prefetch = self._grid_rank_and_prefetch(fn, ctx)
        if rank is None:
            return      # dynamic grid: skipped, not guessed
        expected = rank + prefetch
        for n in ast.walk(fn):
            if not (isinstance(n, ast.Call)
                    and (ctx.full_name(n.func) or "").endswith(".BlockSpec")):
                continue
            lam = None
            if len(n.args) >= 2 and isinstance(n.args[1], ast.Lambda):
                lam = n.args[1]
            for kw in n.keywords:
                if kw.arg == "index_map" and isinstance(kw.value, ast.Lambda):
                    lam = kw.value
            if lam is None:
                continue
            got = len(lam.args.posonlyargs) + len(lam.args.args)
            if got != expected:
                ctx.report(self, lam,
                           f"index_map takes {got} args but the grid has "
                           f"rank {rank} with {prefetch} scalar-prefetch "
                           f"operand(s) (expected {expected}) — Pallas "
                           f"only reports this deep inside tracing")


# --------------------------------------------------------------------------
# R008 — the retrace hazard
# --------------------------------------------------------------------------

@register
class RetraceHazardRule(Rule):
    """Jitted closures must not read mutable engine state at trace time.

    Provenance: DESIGN.md §3.9 and the PR 6/PR 8 dispatch-cache contract.
    The engine's hot path is ONE jitted dispatch whose cache key is
    ``(backend, k, shape, dtype, knobs, index_epoch)``; the index and
    queries flow through as *arguments*.  A fused closure that instead
    reads ``eng.index`` / ``self._tree_index`` at trace time bakes a
    stale snapshot into the executable — online mutations then silently
    search dead state (the capture variant) or force a retrace per
    mutation (the key variant), both of which the zero-retrace tests
    exist to prevent.  The rule flags attribute reads on free-variable
    engine-like names (``self`` / ``eng`` / ``engine``) inside any
    jit-traced function; capture what you need into locals *before* the
    closure (the ``note = eng._note_trace`` idiom in
    search/backends.py), or thread it through the cache key.
    """

    id = "R008"
    title = "jitted closure reads mutable engine state (retrace hazard)"
    provenance = "DESIGN.md §3.9; PR 6 dispatch cache; PR 8 index_epoch"

    def _bound_names(self, root: ast.AST) -> set[str]:
        bound: set[str] = set()
        for n in ast.walk(root):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                a = n.args
                for arg in (a.posonlyargs + a.args + a.kwonlyargs):
                    bound.add(arg.arg)
                if a.vararg:
                    bound.add(a.vararg.arg)
                if a.kwarg:
                    bound.add(a.kwarg.arg)
            elif isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
                bound.add(n.id)
        return bound

    def visit_Attribute(self, node: ast.Attribute, ctx: FileContext) -> None:
        if not (isinstance(node.value, ast.Name)
                and node.value.id in ENGINE_NAMES):
            return
        # innermost traced root containing this read
        root = None
        cur = node
        while cur is not None:
            if cur in ctx.traced_functions:
                root = cur
                break
            cur = ctx.parents.get(cur)
        if root is None:
            return
        if node.value.id in self._bound_names(root):
            return      # the root's own parameter / local, not a capture
        ctx.report(self, node,
                   f"traced body reads {node.value.id}.{node.attr} — "
                   f"mutable engine state must flow through arguments or "
                   f"the dispatch-cache key (DESIGN.md §3.9; capture "
                   f"into a local before the closure like "
                   f"search/backends.py's `note = eng._note_trace`)")
