# lint-fixture-path: src/repro/kernels/fixture_r007.py
"""R007 fixtures: pallas_call index_map arity and unread kernel operands."""
import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def bad_arity(x):
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    grid = (4, 4)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],  # EXPECT: R007
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((32, 32), x.dtype),
    )(x)


def bad_unread_validity(x, row_valid):
    def kernel(db_ref, rv_ref, o_ref):  # EXPECT: R007
        # rv_ref accepted but never read: tombstones silently unmasked
        o_ref[...] = db_ref[...] * 2.0

    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0)),
                  pl.BlockSpec((8, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, 8), x.dtype),
    )(x, row_valid)


def bad_prefetch_arity(x, order):
    def kernel(ord_ref, x_ref, o_ref):
        o_ref[...] = x_ref[...] + ord_ref[0]

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],  # EXPECT: R007
        out_specs=pl.BlockSpec((8,), lambda i, ord_: (i,)),
    )
    return pl.pallas_call(
        kernel, grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((32,), x.dtype))(order, x)


def good_matching(x, row_valid):
    def kernel(db_ref, rv_ref, o_ref):
        vmask = rv_ref[...][:, 0] > 0
        o_ref[...] = db_ref[...] * vmask[:, None]

    grid = (4, 2)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j)),
                  pl.BlockSpec((8, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((32, 16), x.dtype),
    )(x, row_valid)


def good_dynamic_grid(x, grid):
    # grid rank not statically resolvable: skipped, not guessed
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((32,), x.dtype),
    )(x)


def suppressed_scratch_operand(x):
    def kernel(x_ref, scratch_ref, o_ref):  # repro-lint: disable=R007  # EXPECT-SUPPRESSED: R007
        o_ref[...] = x_ref[...]

    return kernel
