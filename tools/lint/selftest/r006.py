# lint-fixture-path: src/repro/kernels/fixture_r006.py
"""R006 fixtures: fp64 / x64 mode inside a device-path module."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64  # flagged at use, not import


def bad_jnp_dtype(x):
    return x.astype(jnp.float64)  # EXPECT: R006


def bad_np_dtype(x):
    return np.float64(x)  # EXPECT: R006


def bad_dtype_string(x):
    return x.astype("float64")  # EXPECT: R006


def bad_x64_toggle():
    jax.config.update("jax_enable_x64", True)  # EXPECT: R006


def bad_x64_context(x):
    with enable_x64():  # EXPECT: R006
        return jnp.asarray(x)


def good_fp32(x):
    return x.astype(jnp.float32)


def good_accum(x):
    return jnp.sum(x, dtype=jnp.float32)


def suppressed(x):
    return x.astype(jnp.float64)  # repro-lint: disable=R006  # EXPECT-SUPPRESSED: R006
