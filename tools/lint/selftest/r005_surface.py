# lint-fixture-path: src/repro/core/distributed.py
"""R005 scoping in distributed.py: only the mutation surface is in scope
(ShardedMutationOps / make_sharded_mutation); the §3.6/§3.7 search-side
merge collectives in the same file are legal by design."""
import jax
import jax.numpy as jnp
from jax import lax


class ShardedMutationOps:
    def insert(self, shard, row):
        lax.psum(jnp.ones(()), "shards")  # EXPECT: R005
        return shard

    def replicated_row_ids(self, ids):
        # whitelisted even inside the surface class
        return jax.lax.all_gather(ids, "shards")


def make_sharded_mutation(handle):
    def _delete(shard, ids):
        return lax.pmax(ids, "shards")  # EXPECT: R005
    return _delete


def sharded_search_local(scores, k):
    # search path, not mutation surface: the tau merge's collective is fine
    top = jax.lax.top_k(scores, k)
    return lax.pmax(top[1].astype(jnp.float32), "shards")
