# lint-fixture-path: tools/fixture_r003.py
"""R003 fixtures: subprocess spawns must pin JAX_PLATFORMS in scope."""
import os
import subprocess
import sys
from subprocess import check_call


def bad_run():
    subprocess.run([sys.executable, "-c", "pass"])  # EXPECT: R003


def bad_popen():
    return subprocess.Popen([sys.executable, "worker.py"])  # EXPECT: R003


def bad_from_import():
    check_call([sys.executable, "-m", "pytest"])  # EXPECT: R003


def good_env_literal():
    env = {**os.environ, "JAX_PLATFORMS": "cpu"}
    subprocess.run([sys.executable, "-c", "pass"], env=env)


def good_setdefault():
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.Popen([sys.executable, "worker.py"], env=env)


def good_not_a_spawn():
    subprocess.list2cmdline([sys.executable])


def suppressed_env_built_elsewhere(env):
    # env is assembled by the caller; the suppression makes that reviewable
    check_call(["ruff", "check"], env=env)  # repro-lint: disable=R003  # EXPECT-SUPPRESSED: R003
