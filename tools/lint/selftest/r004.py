# lint-fixture-path: src/repro/search/fixture_r004.py
"""R004 fixtures: host-sync calls inside jit-traced bodies."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def bad_decorated(x):
    v = x.sum().item()  # EXPECT: R004
    f = float(jnp.max(x))  # EXPECT: R004
    a = np.asarray(x)  # EXPECT: R004
    return v, f, a


@functools.partial(jax.jit, static_argnums=(1,))
def bad_partial_jit(x, k):
    return int(x.argmax())  # EXPECT: R004


bad_jitted_lambda = jax.jit(lambda x: float(jnp.sum(x)))  # EXPECT: R004


def good_host_side(x):
    # not traced: host conversion is exactly where it belongs
    return float(jnp.max(x)), x.sum().item(), np.asarray(x)


@jax.jit
def good_static_shape_math(x):
    n = int(x.shape[0])  # python int of a static shape: no sync
    return x * n


@jax.jit
def good_pure_jnp(x):
    return jnp.maximum(x, 0.0).sum()


@jax.jit
def suppressed(x):
    return float(jnp.max(x))  # repro-lint: disable=R004  # EXPECT-SUPPRESSED: R004
