# lint-fixture-path: src/repro/dist/compat.py
"""R002 negative: dist/compat.py is the one sanctioned shim location."""
import jax


def shard_map(f, mesh, in_specs, out_specs):
    return jax.experimental.shard_map.shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def optimization_barrier(x):
    return jax.lax.optimization_barrier(x)
