# lint-fixture-path: src/repro/search/fixture_r008.py
"""R008 fixtures: jitted closures reading mutable engine state."""
import jax


class Engine:
    def make_fused_bad(self):
        @jax.jit
        def fused(index, queries):
            # trace-time capture of mutable engine state: stale snapshot
            return index @ queries.T * self.tau  # EXPECT: R008
        return fused

    def make_fused_good(self):
        # the backends.py idiom: capture into locals BEFORE the closure
        tau = self.tau
        note = self._note_trace

        @jax.jit
        def fused(index, queries):
            note()
            return index @ queries.T * tau
        return fused

    def dispatch_good(self, entry):
        # NOT jitted: the engine fetches self.index at call time — legal,
        # this is exactly engine.py's non-donate wrapper
        return lambda q: entry(self.index, q)


def make_bad_lambda(eng):
    return jax.jit(lambda q: eng.index @ q.T)  # EXPECT: R008


def make_good_threaded(eng):
    body = jax.jit(lambda index, q: index @ q.T)
    return lambda q: body(eng.index, q)


@jax.jit
def good_param_named_self(self, q):
    # 'self' is a parameter of the traced function, not a capture:
    # the attribute read flows through an argument, which is the contract
    return self.T @ q


def make_suppressed(eng):
    return jax.jit(lambda q: eng.static_dim * q)  # repro-lint: disable=R008  # EXPECT-SUPPRESSED: R008
