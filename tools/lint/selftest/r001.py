# lint-fixture-path: benchmarks/fixture_r001.py
"""R001 fixtures: the TopkRewriter-breaking double subscript."""
import jax
from jax import lax


def bad(scores, k):
    return jax.lax.top_k(scores, k)[0][:, -1]  # EXPECT: R001


def bad_alias(scores, k):
    vals = lax.top_k(scores, k)[0][:, -1]  # EXPECT: R001
    return vals


def bad_integer_index(scores, k):
    return lax.top_k(scores, k)[0][-1]  # EXPECT: R001


def good_tuple_unpack(scores, k):
    # the tree.py idiom: unpack, then barrier before slicing — the slice
    # is on a barrier output, not on top_k(...)[0]
    top_s, sel = jax.lax.top_k(scores, k)
    return top_s[:, -1], sel


def good_values_only(scores, k):
    # taking [0] alone keeps the intact [m, k] block: rewriter-safe
    return jax.lax.top_k(scores, k)[0]


def good_other_function(scores, k):
    return sorted(scores)[0][:k]  # not top_k


def suppressed(scores, k):
    # deliberate, reviewed site
    return lax.top_k(scores, k)[0][:, -1]  # repro-lint: disable=R001  # EXPECT-SUPPRESSED: R001
