# lint-fixture-path: src/repro/core/pivots.py
"""R006 negative: build/oracle modules legitimately use fp64
(DESIGN.md §3.8: fp64 at build, fp32 stored)."""
import numpy as np


def build_pivot_table(db, pivots):
    # the oracle math runs in float64 on the host, by design
    sims = np.float64(db) @ np.float64(pivots).T
    return sims.astype("float64")
