# lint-fixture-path: src/repro/search/fixture_r002.py
"""R002 fixtures: version-shimmed jax APIs outside dist/compat.py."""
import jax
import jax.experimental.shard_map  # EXPECT: R002
from jax.experimental import shard_map  # EXPECT: R002


def bad_call(f, mesh, specs):
    return jax.shard_map(f, mesh=mesh, in_specs=specs)  # EXPECT: R002


def bad_barrier(x):
    return jax.lax.optimization_barrier(x)  # EXPECT: R002


def bad_process_local(sh, x):
    return jax.make_array_from_process_local_data(sh, x)  # EXPECT: R002


def good_compat_import(x):
    from repro.dist.compat import shard_map, optimization_barrier
    return optimization_barrier(shard_map(x))


def good_unrelated_jax(x):
    return jax.lax.top_k(x, 4)


def suppressed(f, mesh):
    return jax.shard_map(f, mesh=mesh)  # repro-lint: disable=R002  # EXPECT-SUPPRESSED: R002
