# lint-fixture-path: src/repro/core/online.py
"""R005 fixtures: collectives in the online-mutation surface."""
import jax
import jax.numpy as jnp
from jax import lax


def insert_rows(shard, row):
    total = lax.psum(jnp.ones(()), "shards")  # EXPECT: R005
    return shard.at[0].set(row), total


def delete_rows(shard, ids):
    mirror = jax.lax.all_gather(ids, "shards")  # EXPECT: R005
    return shard, mirror


def replicated_row_ids(ids):
    # THE whitelisted site: the id-mirror re-replication (DESIGN.md §3.10)
    return jax.lax.all_gather(ids, "shards")


def grow_shard(shard, factor):
    # collective-free mutation: placement is a pure function of
    # replicated host state
    return jnp.pad(shard, ((0, shard.shape[0] * (factor - 1)), (0, 0)))


def suppressed_migration(x):
    return lax.ppermute(x, "shards", [(0, 1)])  # repro-lint: disable=R005  # EXPECT-SUPPRESSED: R005
