# lint-fixture-path: src/repro/kernels/ref.py
"""R001 negative: kth_value in kernels/ref.py is the sanctioned site."""
import jax


def kth_value(scores, k):
    # the real kth_value wraps this in optimization_barrier; the rule
    # exempts exactly this (path, function) pair, so even the raw
    # inline pattern stays silent here
    return jax.lax.top_k(scores, k)[0][:, -1]


def other_function(scores, k):
    # same file, different function: NOT exempt
    return jax.lax.top_k(scores, k)[0][:, -1]  # EXPECT: R001
