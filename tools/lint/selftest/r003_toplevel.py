# lint-fixture-path: tools/fixture_r003_toplevel.py
"""R003 negative: a module-level spawn sees the module as its scope."""
import os
import subprocess
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
subprocess.run([sys.executable, "-c", "pass"], env=dict(os.environ))
