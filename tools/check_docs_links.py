#!/usr/bin/env python
"""Fail CI on broken cross-file links in the repo's markdown docs.

Scans every tracked ``*.md`` file for inline markdown links and checks
that relative targets exist on disk (resolved against the linking file's
directory).  External links (http/https/mailto) and pure in-page anchors
(``#section``) are skipped; an anchor suffix on a file link is stripped
before the existence check.  Exit code 1 with one line per broken link.

Usage: python tools/check_docs_links.py [root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
SKIP_DIRS = {".git", ".venv", "node_modules", "__pycache__"}


def iter_md_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not SKIP_DIRS.intersection(p.name for p in path.parents):
            yield path


def check(root: Path) -> list[str]:
    errors = []
    for md in iter_md_files(root):
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(SKIP_PREFIXES):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not (md.parent / rel).resolve().exists():
                    errors.append(
                        f"{md.relative_to(root)}:{lineno}: broken link "
                        f"-> {target}")
    return errors


if __name__ == "__main__":
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    errors = check(root.resolve())
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        sys.exit(1)
    print(f"docs links ok ({root.resolve().name})")
