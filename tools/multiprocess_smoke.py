#!/usr/bin/env python
"""Multi-host sharded search smoke: N processes x M virtual CPU devices.

The one-command doctor for DESIGN.md §3.7 — proves the process-local
build (`SearchEngine.build(..., distributed=True)`) serves the same
datastore as the single-controller path, across real process boundaries:

  1. a **reference pass** runs in one subprocess with N*M virtual devices
     (the PR-4 single-controller sharded backend, flat and `tree_shards`)
     and records sims/ids/stats plus the fp64 brute-force oracle;
  2. N **worker processes** (`jax.distributed.initialize`, gloo CPU
     collectives, M virtual devices each) each build the index from ONLY
     their own shard rows and run the same searches over the global mesh;
  3. every worker asserts the multi-process results are **bit-identical**
     to the single-process sharded pass (sims exactly equal; ids
     tie-aware), match brute force on the valid prefix, and that the
     per-shard descent (`tree_shards=True`) prunes at least what the
     flat per-shard scan does;
  4. every participant then replays the same fixed online
     insert/delete/reoptimize sequence (DESIGN.md §3.10); workers assert
     the host-side id -> (shard, slot) mirrors and the post-mutation
     search results stay bit-identical to the reference — placement is a
     pure function of replicated host state, decided with zero extra
     collectives.

`JAX_PLATFORMS=cpu` is pinned in every subprocess: the container ships a
TPU plugin with no TPU attached, and backend autodetection otherwise
stalls minutes in GCP-metadata retries.

Run locally (2 processes x 4 devices, the CI shape):
  PYTHONPATH=src python tools/multiprocess_smoke.py

`--json PATH` writes the exactness rows in the `pruning_power` payload
shape; `benchmarks/pruning_power.py` lifts them into the bench-gate run
so `multiprocess_matches_brute` is a REQUIRED_EXACTNESS row
(tools/check_bench_regression.py).
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import tempfile

os.environ.setdefault("JAX_PLATFORMS", "cpu")

SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
K_SWEEP = (7, 80)   # below / above the block size: both merges engage


def _corpus(rows: int, dim: int, n_queries: int):
    """Deterministic clustered corpus (same recipe as tools/sharded_smoke).

    Every participant regenerates it from the seed; workers then keep only
    their own shard rows — the full array exists host-side only as the
    test's data source, never inside any worker's index build.
    """
    import numpy as np

    from repro.core import ref
    rng = np.random.default_rng(11)
    c = ref.normalize(rng.normal(size=(6, dim)))
    db = ref.normalize(c[rng.integers(0, 6, rows)]
                       + 0.05 * rng.normal(size=(rows, dim))).astype(np.float32)
    q = ref.normalize(db[:: max(1, rows // n_queries)][:n_queries]
                      + 0.01 * rng.normal(size=(n_queries, dim))
                      ).astype(np.float32)
    return db, q


def _engines(db_or_local, mesh, args, *, distributed: bool):
    from repro.search import SearchEngine
    kw = dict(n_pivots=args.pivots, block_size=args.block_size, mesh=mesh)
    if distributed:
        kw.update(distributed=True, global_rows=args.rows)
    flat = SearchEngine.build(db_or_local, tree_shards=False, **kw)
    tree = SearchEngine.build(db_or_local, tree_shards=True, **kw)
    return {"flat": flat, "tree": tree}


def _search_all(engines, q, ks):
    import jax.numpy as jnp
    import numpy as np
    out = {}
    for name, eng in engines.items():
        for k in ks:
            sims, ids, stats = eng.search(jnp.asarray(q), k)
            out[f"{name}_k{k}_sims"] = np.asarray(sims)
            out[f"{name}_k{k}_ids"] = np.asarray(ids)
            out[f"{name}_k{k}_blk"] = np.float64(stats.block_prune_frac)
            if name == "tree":
                out[f"{name}_k{k}_tfrac"] = np.float64(stats.tree_prune_frac)
                out[f"{name}_k{k}_evfrac"] = np.float64(
                    stats.tree_node_eval_frac)
    return out


def _mutation_all(engines, args):
    """Fixed seeded online-mutation phase (DESIGN.md §3.10).

    Every participant replays the SAME insert/delete/reoptimize sequence.
    Placement decisions are pure host code over replicated mirrors — no
    collective runs to decide them — so the id -> (shard, slot) digest
    below, computed from each process's OWN host mirror, must agree
    across processes and with the single-process reference.  The
    sequence covers tail fills, a block append on every shard (the big
    insert overflows the free lists), a per-shard repack, and
    post-repack placement.
    """
    import numpy as np

    import jax.numpy as jnp
    from repro.core import ref

    db, q = _corpus(args.rows, args.dim, args.queries)
    rng = np.random.default_rng(17)
    n_new = 2 * args.block_size + 7
    new = ref.normalize(rng.normal(size=(n_new, args.dim))).astype(np.float32)
    dead = sorted(int(x) for x in rng.choice(args.rows, size=25,
                                             replace=False))
    live = {i: db[i] for i in range(args.rows)}
    live.update((args.rows + j, new[j]) for j in range(n_new))
    for i in dead:
        del live[i]
    live_ids = np.array(sorted(live), np.int64)

    out = {"online_live_ids": live_ids}
    for name, eng in engines.items():
        h = eng.online(auto_reoptimize=False)
        got = h.insert(new[:9])
        assert got == list(range(args.rows, args.rows + 9)), got
        h.delete(dead)
        h.insert(new[9:-4])          # overflows the tails: grows every shard
        h.reoptimize()
        h.insert(new[-4:])           # post-repack placement
        place = np.array(sorted((i, s, sl)
                                for i, (s, sl) in h._id_pos.items()),
                         np.int64)
        out[f"online_{name}_place"] = place
        for k in K_SWEEP:
            sims, ids, _stats = eng.search(jnp.asarray(q), k)
            out[f"online_{name}_k{k}_sims"] = np.asarray(sims)
            out[f"online_{name}_k{k}_ids"] = np.asarray(ids)
    return out, q, live, live_ids


def single_ref(args) -> int:
    """Reference pass: single-process sharded engine + fp64 brute oracle."""
    import numpy as np

    import jax
    from repro.core import ref

    db, q = _corpus(args.rows, args.dim, args.queries)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    engines = _engines(db, mesh, args, distributed=False)
    out = _search_all(engines, q, K_SWEEP)
    for k in K_SWEEP:
        sref, iref = ref.brute_force_knn(q, db, min(k, args.rows))
        out[f"brute_k{k}_sims"] = sref
        out[f"brute_k{k}_ids"] = iref
    mut, qm, live, live_ids = _mutation_all(engines, args)
    out.update(mut)
    rows_live = np.stack([live[int(i)] for i in live_ids])
    for k in K_SWEEP:
        kb = min(k, live_ids.size)
        sref, iref = ref.brute_force_knn(qm, rows_live, kb)
        out[f"online_brute_k{k}_sims"] = sref
        out[f"online_brute_k{k}_ids"] = live_ids[iref]
    np.savez(args.single_ref, n_devices=jax.device_count(), **out)
    print(f"reference pass ok: {jax.device_count()} devices -> "
          f"{args.single_ref}")
    return 0


def worker(args) -> int:
    """One multi-process worker: process-local build, global search, verify."""
    # gloo collectives + distributed.initialize must run before anything
    # touches the backend
    sys.path.insert(0, SRC)
    from repro.dist.compat import multiprocess_cpu_init
    multiprocess_cpu_init(f"127.0.0.1:{args.port}", args.nproc, args.worker)

    import numpy as np

    import jax
    import jax.numpy as jnp
    from repro.core.distributed import local_shard_rows

    pid = jax.process_index()
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    db, q = _corpus(args.rows, args.dim, args.queries)
    _, owned = local_shard_rows(args.rows, mesh)
    db_local = np.concatenate([db[start:stop] for _, start, stop in owned])
    del db                      # the index build sees only the local rows

    engines = _engines(db_local, mesh, args, distributed=True)
    assert engines["flat"].backend_name == "sharded"
    got = _search_all(engines, q, K_SWEEP)

    ref_npz = np.load(args.ref)
    assert int(ref_npz["n_devices"]) == jax.device_count(), (
        int(ref_npz["n_devices"]), jax.device_count())
    failures = []
    for name in ("flat", "tree"):
        for k in K_SWEEP:
            sims, ids = got[f"{name}_k{k}_sims"], got[f"{name}_k{k}_ids"]
            rs, ri = ref_npz[f"{name}_k{k}_sims"], ref_npz[f"{name}_k{k}_ids"]
            if not np.array_equal(sims, rs):
                failures.append(
                    f"{name} k={k}: sims not bit-identical to the "
                    f"single-process sharded pass (max |d| = "
                    f"{np.abs(sims - rs).max()})")
            if not np.array_equal(np.sort(ids, 1), np.sort(ri, 1)):
                failures.append(f"{name} k={k}: id sets differ from the "
                                f"single-process sharded pass")
            kb = min(k, args.rows)
            bs, bi = ref_npz[f"brute_k{k}_sims"], ref_npz[f"brute_k{k}_ids"]
            if not np.allclose(sims[:, :kb], bs, atol=3e-5):
                failures.append(f"{name} k={k}: sims diverge from fp64 brute")
            if not np.array_equal(np.sort(ids[:, :kb], 1), np.sort(bi, 1)):
                failures.append(f"{name} k={k}: id set != brute (tie-aware)")
            if kb < k and not (np.all(ids[:, kb:] == -1)
                               and np.all(np.isneginf(sims[:, kb:]))):
                failures.append(f"{name} k={k}: (-inf, -1) fill violated "
                                f"past row {kb}")
    for k in K_SWEEP:
        flat_blk = float(got[f"flat_k{k}_blk"])
        tree_blk = float(got[f"tree_k{k}_blk"])
        tfrac = float(got[f"tree_k{k}_tfrac"])
        if tree_blk < flat_blk - 1e-6:
            failures.append(f"k={k}: tree total pruning {tree_blk:.4f} < "
                            f"flat {flat_blk:.4f}")
        if tfrac < flat_blk - 1e-6:
            failures.append(f"k={k}: per-shard descent pruning {tfrac:.4f} "
                            f"< flat per-shard pruning {flat_blk:.4f}")
        if not np.allclose(flat_blk, float(ref_npz[f"flat_k{k}_blk"]),
                           rtol=1e-6):
            failures.append(f"k={k}: flat stats diverge from single-process")

    # --- online-mutation phase: deterministic cross-host row placement ---
    mut, qm, live, live_ids = _mutation_all(engines, args)
    if not np.array_equal(mut["online_flat_place"],
                          mut["online_tree_place"]):
        failures.append("online: flat/tree placement digests disagree "
                        "within one process")
    for name in ("flat", "tree"):
        if not np.array_equal(mut[f"online_{name}_place"],
                              ref_npz[f"online_{name}_place"]):
            failures.append(
                f"online {name}: id->(shard,slot) digest differs from the "
                f"single-process reference — host mirrors drifted")
        for k in K_SWEEP:
            sims = mut[f"online_{name}_k{k}_sims"]
            ids = mut[f"online_{name}_k{k}_ids"]
            rs = ref_npz[f"online_{name}_k{k}_sims"]
            ri = ref_npz[f"online_{name}_k{k}_ids"]
            if not np.array_equal(sims, rs):
                failures.append(f"online {name} k={k}: sims not "
                                f"bit-identical after mutations")
            if not np.array_equal(np.sort(ids, 1), np.sort(ri, 1)):
                failures.append(f"online {name} k={k}: id sets differ "
                                f"after mutations")
            kb = min(k, live_ids.size)
            bs_ = ref_npz[f"online_brute_k{k}_sims"]
            bi = ref_npz[f"online_brute_k{k}_ids"]
            if not np.allclose(sims[:, :kb], bs_, atol=3e-5):
                failures.append(f"online {name} k={k}: sims diverge from "
                                f"fp64 brute on the mutated live set")
            if not np.array_equal(np.sort(ids[:, :kb], 1), np.sort(bi, 1)):
                failures.append(f"online {name} k={k}: id set != brute on "
                                f"the mutated live set (tie-aware)")
    for f in failures:
        print(f"[proc {pid}] FAIL: {f}", file=sys.stderr)
    if not failures:
        k = K_SWEEP[0]
        print(f"[proc {pid}] ok: {args.nproc} processes x "
              f"{jax.local_device_count()} devices, flat block_prune="
              f"{float(got[f'flat_k{k}_blk']):.3f}, tree_prune="
              f"{float(got[f'tree_k{k}_tfrac']):.3f}")
    return 1 if failures else 0


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def launch(args) -> int:
    """Spawn the reference pass, then the worker fleet; aggregate results."""
    def env_with(devices: int) -> dict:
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        return env

    size_args = ["--rows", str(args.rows), "--dim", str(args.dim),
                 "--queries", str(args.queries), "--block-size",
                 str(args.block_size), "--pivots", str(args.pivots)]
    me = os.path.abspath(__file__)
    with tempfile.TemporaryDirectory(prefix="mp_smoke_") as tmp:
        ref_path = os.path.join(tmp, "single_ref.npz")
        r = subprocess.run(
            [sys.executable, me, "--single-ref", ref_path] + size_args,
            env=env_with(args.processes * args.devices), timeout=900)
        if r.returncode != 0:
            print("single-process reference pass failed", file=sys.stderr)
            return 1
        port = _free_port()
        workers = [
            subprocess.Popen(
                [sys.executable, me, "--worker", str(i), "--nproc",
                 str(args.processes), "--port", str(port), "--ref",
                 ref_path] + size_args,
                env=env_with(args.devices))
            for i in range(args.processes)
        ]
        rcs = []
        for w in workers:
            try:
                rcs.append(w.wait(timeout=900))
            except subprocess.TimeoutExpired:
                w.kill()
                rcs.append(-9)
    ok = all(rc == 0 for rc in rcs)
    if args.json:
        payload = {
            "benchmark": "pruning_power",
            "quick": False,
            "metrics": [
                {"name": "pruning/multihost/multiprocess_matches_brute",
                 "value": 1.0 if ok else 0.0,
                 "note": f"{args.processes} processes x {args.devices} "
                         f"devices, bit-identical to single-process "
                         f"sharded; exactness gate: must be 1.0"},
            ],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    if ok:
        print(f"multiprocess smoke ok: {args.processes} processes x "
              f"{args.devices} devices")
        return 0
    print(f"multiprocess smoke FAILED (worker rcs {rcs})", file=sys.stderr)
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices", type=int, default=4,
                    help="virtual CPU devices per process")
    ap.add_argument("--rows", type=int, default=4099)
    ap.add_argument("--dim", type=int, default=24)
    ap.add_argument("--queries", type=int, default=9)
    ap.add_argument("--block-size", type=int, default=64)
    ap.add_argument("--pivots", type=int, default=8)
    ap.add_argument("--json", metavar="PATH",
                    help="write exactness rows (pruning_power payload shape)")
    # internal entry points (spawned by launch)
    ap.add_argument("--single-ref", metavar="NPZ", help=argparse.SUPPRESS)
    ap.add_argument("--worker", type=int, default=None, help=argparse.SUPPRESS)
    ap.add_argument("--nproc", type=int, help=argparse.SUPPRESS)
    ap.add_argument("--port", type=int, help=argparse.SUPPRESS)
    ap.add_argument("--ref", metavar="NPZ", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.single_ref:
        sys.path.insert(0, SRC)
        return single_ref(args)
    if args.worker is not None:
        return worker(args)
    return launch(args)


if __name__ == "__main__":
    sys.exit(main())
