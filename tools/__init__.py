"""Repo tooling (CI gates, smokes, and the repro-lint static analyzer).

A regular package so ``python -m tools.lint`` and ``import tools.lint``
work from the repo root (pytest already puts ``.`` and ``src`` on the
path via pyproject's ``pythonpath``).  The standalone scripts in this
directory are still run directly (``python tools/check_docs_links.py``).
"""
