#!/usr/bin/env python
"""Exercise the ``sharded`` SearchEngine backend on the current device set.

Meant for the CI multi-device job, which sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so a hosted runner
presents eight virtual CPU devices: builds a mesh over ALL visible
devices, shards a clustered datastore across it, and checks the sharded
engine (τ warm-start + best-first applied per shard, element stats on)
against fp64 brute force.  Exits non-zero on any mismatch.

Run locally:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \\
      PYTHONPATH=src python tools/sharded_smoke.py
"""
from __future__ import annotations

import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")   # never stall on TPU probing

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def main() -> int:
    from repro.core import ref
    from repro.search import SearchEngine

    n_dev = jax.device_count()
    if n_dev < 2:
        print(f"sharded smoke needs >= 2 devices, found {n_dev}; set "
              f"XLA_FLAGS=--xla_force_host_platform_device_count=8",
              file=sys.stderr)
        return 2

    rng = np.random.default_rng(11)
    c = ref.normalize(rng.normal(size=(6, 24)))
    db = ref.normalize(c[rng.integers(0, 6, 4099)]
                       + 0.05 * rng.normal(size=(4099, 24))).astype(np.float32)
    q = ref.normalize(db[::500] + 0.01 * rng.normal(size=(9, 24))
                      ).astype(np.float32)

    mesh = jax.make_mesh((n_dev,), ("data",))
    eng = SearchEngine.build(db, n_pivots=8, block_size=64, mesh=mesh)
    assert eng.backend_name == "sharded", eng.backend_name
    sims, ids, stats = eng.search(jnp.asarray(q), 7, element_stats=True)

    sref, iref = ref.brute_force_knn(q, db, 7)
    np.testing.assert_allclose(np.asarray(sims), sref, atol=2e-5)
    set_match = (np.sort(np.asarray(ids), 1) == np.sort(iref, 1)).mean()
    assert set_match > 0.98, f"id set match {set_match}"
    blk = float(stats.block_prune_frac)
    elem = float(stats.elem_prune_frac)
    assert 0.0 <= blk <= 1.0 and 0.0 <= elem <= 1.0, (blk, elem)
    print(f"sharded smoke ok: {n_dev} devices, block_prune_frac={blk:.3f}, "
          f"elem_prune_frac={elem:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
