#!/usr/bin/env python
"""Exercise the ``sharded`` SearchEngine backend on the current device set.

Meant for the CI multi-device job, which sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so a hosted runner
presents eight virtual CPU devices: builds a mesh over ALL visible
devices, shards a clustered datastore across it, and checks the sharded
engine (τ warm-start + best-first applied per shard, element stats on)
against fp64 brute force — both the flat per-shard scan and the per-shard
pivot-tree descent (``tree_shards=True``, DESIGN.md §3.6).  Exits
non-zero on any mismatch.  The pytest twin with deeper assertions is
tests/test_sharded_tree.py; this script stays as the one-command doctor.

Run locally:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \\
      PYTHONPATH=src python tools/sharded_smoke.py
"""
from __future__ import annotations

import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")   # never stall on TPU probing

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def main() -> int:
    from repro.core import ref
    from repro.search import SearchEngine

    n_dev = jax.device_count()
    if n_dev < 2:
        print(f"sharded smoke needs >= 2 devices, found {n_dev}; set "
              f"XLA_FLAGS=--xla_force_host_platform_device_count=8",
              file=sys.stderr)
        return 2

    rng = np.random.default_rng(11)
    c = ref.normalize(rng.normal(size=(6, 24)))
    db = ref.normalize(c[rng.integers(0, 6, 4099)]
                       + 0.05 * rng.normal(size=(4099, 24))).astype(np.float32)
    q = ref.normalize(db[::500] + 0.01 * rng.normal(size=(9, 24))
                      ).astype(np.float32)

    mesh = jax.make_mesh((n_dev,), ("data",))
    eng = SearchEngine.build(db, n_pivots=8, block_size=64, mesh=mesh)
    assert eng.backend_name == "sharded", eng.backend_name
    sims, ids, stats = eng.search(jnp.asarray(q), 7, element_stats=True)

    sref, iref = ref.brute_force_knn(q, db, 7)
    np.testing.assert_allclose(np.asarray(sims), sref, atol=2e-5)
    set_match = (np.sort(np.asarray(ids), 1) == np.sort(iref, 1)).mean()
    assert set_match > 0.98, f"id set match {set_match}"
    blk = float(stats.block_prune_frac)
    elem = float(stats.elem_prune_frac)
    assert 0.0 <= blk <= 1.0 and 0.0 <= elem <= 1.0, (blk, elem)

    # tree x sharded composition: per-shard Eq. 13 descent with the
    # broadcast global tau (DESIGN.md §3.6) — same result set, pruning at
    # least the flat path's, for k below and above the block size
    treng = SearchEngine.build(db, n_pivots=8, block_size=64, mesh=mesh,
                               tree_shards=True)
    for k in (7, 80):
        ts, ti, tst = treng.search(jnp.asarray(q), k, element_stats=True)
        skref, ikref = ref.brute_force_knn(q, db, k)
        np.testing.assert_allclose(np.asarray(ts), skref, atol=2e-5)
        tmatch = (np.sort(np.asarray(ti), 1) == np.sort(ikref, 1)).mean()
        assert tmatch > 0.98, f"tree id set match {tmatch} at k={k}"
        assert 0.0 <= float(tst.tree_prune_frac) <= 1.0
        assert 0.0 < float(tst.tree_node_eval_frac) <= 1.0
    _, _, tst7 = treng.search(jnp.asarray(q), 7)
    tblk = float(tst7.block_prune_frac)
    assert tblk >= blk - 1e-6, (tblk, blk)

    print(f"sharded smoke ok: {n_dev} devices, block_prune_frac={blk:.3f}, "
          f"elem_prune_frac={elem:.3f}, tree block_prune_frac={tblk:.3f}, "
          f"tree_prune_frac={float(tst7.tree_prune_frac):.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
