#!/usr/bin/env python
"""Fail CI when pruning power regresses against the committed baseline.

Compares a fresh ``benchmarks/pruning_power.py --json`` output against the
checked-in ``BENCH_pruning.json``:

* **exactness gates** (metric names ending in ``_matches_brute``) must be
  exactly 1.0 in the current run — any other value is a hard failure
  regardless of tolerance (a search path stopped returning the brute-force
  result set);
* every other metric is **tolerance-banded in its bad direction only**:
  prune/prunable fractions may not drop by more than ``--tolerance``,
  exact-computed fractions (``*_exact_frac``, ``*_computed_frac``, lower =
  better) may not rise by more than it.  Improvements never fail — they
  are printed as notices suggesting a re-baseline;
* a baseline metric missing from the current run fails (a benchmark row
  was silently dropped); new current-only metrics are informational;
* the two files must have been produced with the same ``--quick`` flag —
  quick and full runs use different corpora and are not comparable.

Exit code 1 with one line per violation.

Usage:
  python tools/check_bench_regression.py --current out.json \\
      [--baseline BENCH_pruning.json] [--tolerance 0.05]
"""
from __future__ import annotations

import argparse
import json
import sys

#: substrings marking "lower = better" metrics (fractions of work done)
LOWER_BETTER = ("exact_frac", "computed_frac", "node_eval_frac")

#: exactness rows every current run MUST produce, baselined or not — a run
#: that silently stops emitting one of these has lost a whole search path
#: (the sharded_tree row is the tree x sharded composition gate)
REQUIRED_EXACTNESS = (
    "scan_matches_brute",
    "tree_matches_brute",
    "sharded_matches_brute",
    "sharded_tree_matches_brute",
)

#: additionally required from FULL runs only: quick mode deliberately
#: skips the multi-process fleet spawn (the dedicated multiprocess CI job
#: covers it there), so only a full run silently losing the row means a
#: search path stopped being exercised
REQUIRED_EXACTNESS_FULL = (
    # the multi-host gate: 2-process distributed build bit-identical to
    # the single-process sharded path (tools/multiprocess_smoke.py)
    "multiprocess_matches_brute",
)


def _load(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("benchmark") != "pruning_power":
        sys.exit(f"{path}: not a pruning_power payload")
    return payload


def compare(baseline: dict, current: dict, tolerance: float):
    errors, notices = [], []
    if bool(baseline.get("quick")) != bool(current.get("quick")):
        errors.append(
            f"quick-mode mismatch: baseline quick={baseline.get('quick')} "
            f"vs current quick={current.get('quick')} — runs are not "
            f"comparable")
        return errors, notices
    base = {m["name"]: m["value"] for m in baseline["metrics"]}
    cur = {m["name"]: m["value"] for m in current["metrics"]}

    for name, bval in base.items():
        if name not in cur:
            errors.append(f"{name}: present in baseline but missing from "
                          f"the current run (benchmark row dropped?)")
            continue
        cval = cur[name]
        if name.endswith("_matches_brute"):
            if cval != 1.0:
                errors.append(f"{name}: EXACTNESS MISMATCH — current "
                              f"{cval} != 1.0 (result set no longer equals "
                              f"brute force); hard failure")
            continue
        lower_better = any(tag in name for tag in LOWER_BETTER)
        delta = cval - bval
        worse = delta > tolerance if lower_better else -delta > tolerance
        better = -delta > tolerance if lower_better else delta > tolerance
        if worse:
            direction = "rose" if lower_better else "dropped"
            errors.append(f"{name}: {direction} {bval:.4f} -> {cval:.4f} "
                          f"(|Δ|={abs(delta):.4f} > tolerance {tolerance})")
        elif better:
            notices.append(f"{name}: improved {bval:.4f} -> {cval:.4f} — "
                           f"consider re-baselining BENCH_pruning.json")

    for name in sorted(set(cur) - set(base)):
        notices.append(f"{name}: new metric (value {cur[name]}), not in "
                       f"baseline — will be gated once baselined")

    # hard-required exactness rows: their absence from the CURRENT run is a
    # failure even if they were never baselined (a path stopped running is
    # as bad as a path going inexact).  Exact match on the metric leaf —
    # substring matching would let sharded_tree_matches_brute satisfy the
    # tree_matches_brute requirement
    leaves = {name.rsplit("/", 1)[-1] for name in cur}
    required = REQUIRED_EXACTNESS
    if not current.get("quick"):
        required = required + REQUIRED_EXACTNESS_FULL
    for tag in required:
        if tag not in leaves:
            errors.append(f"required exactness row {tag} missing from the "
                          f"current run — a search path is no longer "
                          f"exercised by the benchmark")
    return errors, notices


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="gate pruning_power output against the committed "
                    "baseline")
    ap.add_argument("--current", required=True,
                    help="fresh pruning_power.py --json output")
    ap.add_argument("--baseline", default="BENCH_pruning.json",
                    help="committed baseline (default: BENCH_pruning.json)")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="allowed one-sided drift for prune/computed "
                         "fractions (default: 0.05)")
    args = ap.parse_args(argv)

    errors, notices = compare(_load(args.baseline), _load(args.current),
                              args.tolerance)
    for n in notices:
        print(f"note: {n}")
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    if errors:
        print(f"\n{len(errors)} regression(s) vs {args.baseline}",
              file=sys.stderr)
        return 1
    print(f"bench gate ok: {args.baseline} vs {args.current} "
          f"(tolerance {args.tolerance})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
