#!/usr/bin/env python
"""Fail CI when a committed benchmark baseline regresses.

Compares a fresh ``--json`` output against its checked-in baseline.  Two
payload kinds are understood (matched on the payload's ``benchmark`` tag;
baseline and current must agree):

``pruning_power`` (``BENCH_pruning.json``):

* **exactness gates** (metric names ending in ``_matches_brute``) must be
  exactly 1.0 in the current run — any other value is a hard failure
  regardless of tolerance (a search path stopped returning the brute-force
  result set);
* every other metric is **tolerance-banded in its bad direction only**:
  prune/prunable fractions may not drop by more than ``--tolerance``,
  exact-computed fractions (``*_exact_frac``, ``*_computed_frac``, lower =
  better) may not rise by more than it.  Improvements never fail — they
  are printed as notices suggesting a re-baseline.

``latency`` (``BENCH_latency.json``):

* the same ``_matches_brute`` hard gate;
* ``*speedup*`` rows (p50 ratios, higher = better) are banded
  **multiplicatively** by ``--ratio-tolerance``: the gate fails when the
  current ratio falls below ``baseline / (1 + ratio_tolerance)``.  Ratios
  of p50s taken on the same host in the same run are stable where
  absolute microseconds are not — which is why
* absolute ``*_us`` and ``*_qps`` rows are **informational only**: they
  move with the host the run happened on and are never gated.

For both kinds: a baseline metric missing from the current run fails (a
benchmark row was silently dropped, except never-gated ``*_us`` rows);
new current-only metrics are informational; and the two files must have
been produced with the same ``--quick`` flag — quick and full runs use
different corpora and are not comparable.

Exit code 1 with one line per violation.

Usage:
  python tools/check_bench_regression.py --current out.json \\
      [--baseline BENCH_pruning.json] [--tolerance 0.05] \\
      [--ratio-tolerance 0.35]
"""
from __future__ import annotations

import argparse
import json
import sys

#: substrings marking "lower = better" metrics (fractions of work done)
LOWER_BETTER = ("exact_frac", "computed_frac", "node_eval_frac")

#: exactness rows every current run MUST produce, baselined or not — a run
#: that silently stops emitting one of these has lost a whole search path
#: (the sharded_tree row is the tree x sharded composition gate)
REQUIRED_EXACTNESS = (
    "scan_matches_brute",
    "tree_matches_brute",
    "sharded_matches_brute",
    "sharded_tree_matches_brute",
    # scan with the joint multi-pivot cap intersected (DESIGN.md §3.8)
    "multipivot_matches_brute",
)

#: additionally required from FULL runs only: quick mode deliberately
#: skips the multi-process fleet spawn (the dedicated multiprocess CI job
#: covers it there), so only a full run silently losing the row means a
#: search path stopped being exercised
REQUIRED_EXACTNESS_FULL = (
    # the multi-host gate: 2-process distributed build bit-identical to
    # the single-process sharded path (tools/multiprocess_smoke.py)
    "multiprocess_matches_brute",
)

#: exactness rows every latency run must produce: one per measured
#: variant per regime (latency.py emits them per regime; the leaf names
#: are regime-independent)
REQUIRED_EXACTNESS_LATENCY = (
    "brute_matches_brute",
    "base_matches_brute",
    "engine_matches_brute",
    "tree_matches_brute",
    "kernel_matches_brute",
    # sustained serving with interleaved online inserts/deletes must stay
    # brute-equal on the live corpus at every step (DESIGN.md §3.9)
    "online_matches_brute",
    # the same serve loop on a sharded engine with deterministic
    # cross-host placement + mid-run per-shard reoptimize (§3.10)
    "sharded_online_matches_brute",
)

KNOWN_KINDS = ("pruning_power", "latency")


def _load(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("benchmark") not in KNOWN_KINDS:
        sys.exit(f"{path}: not one of {KNOWN_KINDS} "
                 f"(benchmark={payload.get('benchmark')!r})")
    return payload


def compare(baseline: dict, current: dict, tolerance: float,
            ratio_tolerance: float = 0.35):
    errors, notices = [], []
    kind = current.get("benchmark")
    if baseline.get("benchmark") != kind:
        errors.append(
            f"payload-kind mismatch: baseline {baseline.get('benchmark')!r} "
            f"vs current {kind!r} — wrong baseline file?")
        return errors, notices
    if bool(baseline.get("quick")) != bool(current.get("quick")):
        errors.append(
            f"quick-mode mismatch: baseline quick={baseline.get('quick')} "
            f"vs current quick={current.get('quick')} — runs are not "
            f"comparable")
        return errors, notices
    base = {m["name"]: m["value"] for m in baseline["metrics"]}
    cur = {m["name"]: m["value"] for m in current["metrics"]}
    latency = kind == "latency"

    for name, bval in base.items():
        # absolute microseconds and QPS move with the host; only ratios
        # and exactness rows are stable enough to gate
        informational = latency and (name.endswith("_us")
                                     or name.endswith("_qps"))
        if name not in cur:
            if not informational:
                errors.append(f"{name}: present in baseline but missing "
                              f"from the current run (benchmark row "
                              f"dropped?)")
            continue
        cval = cur[name]
        if name.endswith("_matches_brute"):
            if cval != 1.0:
                errors.append(f"{name}: EXACTNESS MISMATCH — current "
                              f"{cval} != 1.0 (result set no longer equals "
                              f"brute force); hard failure")
            continue
        if informational:
            continue            # absolute microseconds move with the host
        if latency and "speedup" in name:
            # multiplicative band on a p50 ratio (higher = better)
            floor = bval / (1.0 + ratio_tolerance)
            ceil_ = bval * (1.0 + ratio_tolerance)
            if cval < floor:
                errors.append(
                    f"{name}: speedup ratio fell {bval:.4f} -> {cval:.4f} "
                    f"(< {floor:.4f}, ratio tolerance {ratio_tolerance})")
            elif cval > ceil_:
                notices.append(f"{name}: speedup improved {bval:.4f} -> "
                               f"{cval:.4f} — consider re-baselining "
                               f"BENCH_latency.json")
            continue
        lower_better = any(tag in name for tag in LOWER_BETTER)
        delta = cval - bval
        worse = delta > tolerance if lower_better else -delta > tolerance
        better = -delta > tolerance if lower_better else delta > tolerance
        if worse:
            direction = "rose" if lower_better else "dropped"
            errors.append(f"{name}: {direction} {bval:.4f} -> {cval:.4f} "
                          f"(|Δ|={abs(delta):.4f} > tolerance {tolerance})")
        elif better:
            notices.append(f"{name}: improved {bval:.4f} -> {cval:.4f} — "
                           f"consider re-baselining the committed baseline")

    for name in sorted(set(cur) - set(base)):
        notices.append(f"{name}: new metric (value {cur[name]}), not in "
                       f"baseline — will be gated once baselined")

    # hard-required exactness rows: their absence from the CURRENT run is a
    # failure even if they were never baselined (a path stopped running is
    # as bad as a path going inexact).  Exact match on the metric leaf —
    # substring matching would let sharded_tree_matches_brute satisfy the
    # tree_matches_brute requirement
    leaves = {name.rsplit("/", 1)[-1] for name in cur}
    if latency:
        required = REQUIRED_EXACTNESS_LATENCY
    else:
        required = REQUIRED_EXACTNESS
        if not current.get("quick"):
            required = required + REQUIRED_EXACTNESS_FULL
    for tag in required:
        if tag not in leaves:
            errors.append(f"required exactness row {tag} missing from the "
                          f"current run — a search path is no longer "
                          f"exercised by the benchmark")
    return errors, notices


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="gate pruning_power output against the committed "
                    "baseline")
    ap.add_argument("--current", required=True,
                    help="fresh pruning_power.py --json output")
    ap.add_argument("--baseline", default="BENCH_pruning.json",
                    help="committed baseline (default: BENCH_pruning.json)")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="allowed one-sided drift for prune/computed "
                         "fractions (default: 0.05)")
    ap.add_argument("--ratio-tolerance", type=float, default=0.35,
                    help="allowed multiplicative drop for latency speedup "
                         "ratios (default: 0.35 — CI-runner medians "
                         "wobble more than pruning fractions)")
    args = ap.parse_args(argv)

    errors, notices = compare(_load(args.baseline), _load(args.current),
                              args.tolerance, args.ratio_tolerance)
    for n in notices:
        print(f"note: {n}")
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    if errors:
        print(f"\n{len(errors)} regression(s) vs {args.baseline}",
              file=sys.stderr)
        return 1
    print(f"bench gate ok: {args.baseline} vs {args.current} "
          f"(tolerance {args.tolerance})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
