"""Quickstart: the paper's bounds and exact search in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import bounds, ref, build_index, search_brute
from repro.core.vptree import VPTree
from repro.search import SearchEngine

rng = np.random.default_rng(0)

# --- 1. the triangle inequality itself (Eq. 10 / Eq. 13) -------------------
x, y, z = ref.normalize(rng.normal(size=(3, 64)))
a, b = float(x @ z), float(z @ y)          # known similarities via pivot z
true = float(x @ y)                        # what we want to bound
lo, hi = float(ref.lb_mult(a, b)), float(ref.ub_mult(a, b))
print(f"sim(x,z)={a:+.3f}  sim(z,y)={b:+.3f}")
print(f"Eq.10/13 bound sim(x,y) in [{lo:+.3f}, {hi:+.3f}]  (true {true:+.3f})")
assert lo - 1e-9 <= true <= hi + 1e-9

# --- 2. exact kNN through the unified SearchEngine -------------------------
centers = ref.normalize(rng.normal(size=(8, 64)))
db = ref.normalize(centers[rng.integers(0, 8, 20_000)]
                   + 0.05 * rng.normal(size=(20_000, 64))).astype(np.float32)
queries = jnp.asarray(db[rng.choice(20_000, 32)])

engine = SearchEngine.build(jnp.asarray(db), n_pivots=16, block_size=128)
sims, ids, stats = engine.search(queries, 10)
sims_b, ids_b = search_brute(engine.index, queries, 10)
np.testing.assert_allclose(np.asarray(sims), np.asarray(sims_b), atol=1e-6)
print(f"\nexact 10-NN over 20k vectors (backend={stats.backend}, "
      f"warm_start={stats.warm_start} best_first={stats.best_first} — "
      f"time-tuned defaults): "
      f"{stats.block_prune_frac:.0%} of (query, block) work pruned, "
      f"results identical to brute force")

# --- 3. the paper-faithful VP-tree, Eq.13 vs chord bound --------------------
vt = VPTree(db[:5000], leaf_size=16)
_, _, frac_mult = vt.knn_batch(np.asarray(queries[:8]), 10, bound="mult")
_, _, frac_eucl = vt.knn_batch(np.asarray(queries[:8]), 10, bound="euclid")
print(f"VP-tree exact-similarity fraction: mult={frac_mult:.3f} "
      f"euclid={frac_eucl:.3f}  (lower is better; Eq. 13 wins)")
