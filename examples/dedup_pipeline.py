"""Data-layer example: near-duplicate filtering with the paper's bounds.

Builds a corpus with planted near-duplicates, embeds it, and removes dupes
via exact threshold search — the sim→1 regime where Eq. 13 pruning is
strongest.

    PYTHONPATH=src python examples/dedup_pipeline.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.data.dedup import dedup_mask, embed_tokens, find_near_duplicates

rng = np.random.default_rng(0)
n_docs, seq = 400, 128
tokens = rng.integers(0, 5000, size=(n_docs, seq))

# plant duplicates: 40 docs are near-copies of earlier ones
for i in range(40):
    src, dst = rng.integers(0, 200), 200 + i
    tokens[dst] = tokens[src]
    flip = rng.integers(0, seq, 4)          # 4 token edits
    tokens[dst, flip] = rng.integers(0, 5000, 4)

emb = embed_tokens(tokens, dim=256)
pairs, stats = find_near_duplicates(emb, threshold=0.9, k=8)
keep = dedup_mask(n_docs, pairs)
print(f"{len(pairs)} near-duplicate pairs found; "
      f"{(~keep).sum()} docs dropped of {n_docs}")
print(f"search stats: {stats}")
planted_found = sum(1 for i, j in pairs if 200 <= j < 240)
print(f"planted duplicates recovered: {planted_found}/40")
assert planted_found >= 38
