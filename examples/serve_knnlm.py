"""End-to-end driver: serve a small LM with batched requests + kNN-LM.

This is the assignment's end-to-end example (serving flavor): build a model,
harvest a retrieval datastore from its own hidden states, then serve a batch
of requests where every decode step runs the paper's bound-pruned exact
search over the datastore and interpolates the next-token distribution.

    PYTHONPATH=src python examples/serve_knnlm.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

main(["--arch", "tinyllama-1.1b", "--smoke", "--requests", "8",
      "--prompt-len", "32", "--gen", "16", "--knn"])
