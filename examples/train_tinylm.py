"""End-to-end training driver: a few hundred steps on synthetic data with
checkpointing, preemption safety, and the straggler watchdog active.

Uses a reduced tinyllama-family config sized for this CPU container; on a
TPU slice the same driver takes the full config + --mesh pod (see
launch/train.py).

    PYTHONPATH=src python examples/train_tinylm.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

out = main(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "200",
            "--batch", "8", "--seq", "64", "--lr", "1e-3",
            "--ckpt-dir", "/tmp/repro_train_example", "--ckpt-every", "100"])
losses = [h["loss"] for h in out["history"]]
assert losses[-1] < losses[0], "training must reduce loss"
print("OK: loss decreased", round(losses[0], 3), "->", round(losses[-1], 3))
