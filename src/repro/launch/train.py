"""Training driver.

CPU-scale demo (this container)::

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 64

Production shape (on a real pod slice, same code: remove --smoke, point
--mesh at the pod): builds the (data, model) mesh, installs sharding rules,
shards params/opt with the dry-run's param_shardings, and runs the Trainer
with async checkpointing, preemption handling, and the straggler watchdog.
"""
from __future__ import annotations

import argparse

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default="synthetic",
                    help="'synthetic' or a path to an int32 token file")
    ap.add_argument("--mesh", default="host",
                    help="host | pod (16x16) | multipod (2x16x16)")
    args = ap.parse_args(argv)

    from functools import partial
    from repro.configs import ARCHS, smoke_config
    from repro.data.pipeline import SyntheticLM, TokenFileSource
    from repro.dist import sharding as shd
    from repro.models import model_fns
    from repro.optim import schedule
    from repro.train.train_step import init_state, make_train_step
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = smoke_config(args.arch) if args.smoke else ARCHS[args.arch]
    fns = model_fns(cfg)

    make_global = None
    if args.mesh != "host":
        from repro.dist.compat import make_process_local_array
        from repro.launch.mesh import make_production_mesh
        from repro.launch.dryrun import param_shardings
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
        shd.set_rules(mesh, shd.default_rules(
            multi_pod=(args.mesh == "multipod"), fsdp=True))
        dp = ("pod", "data") if args.mesh == "multipod" else ("data",)
        batch_sh = NamedSharding(mesh, P(dp))
        # batch is sharded over all processes along axis 0 only, so the
        # global row count is the local one scaled by process count
        make_global = lambda b: jax.tree.map(
            lambda x: make_process_local_array(
                batch_sh, x,
                (x.shape[0] * jax.process_count(),) + tuple(x.shape[1:])), b)

    step_fn = jax.jit(make_train_step(
        fns, cfg,
        lr_schedule=partial(schedule.warmup_cosine, peak_lr=args.lr,
                            warmup_steps=max(args.steps // 20, 5),
                            total_steps=args.steps),
        accum=args.accum, compress_grads=args.compress_grads))
    state = init_state(fns, jax.random.PRNGKey(0),
                       compress_grads=args.compress_grads)

    if args.data == "synthetic":
        data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
    else:
        data = TokenFileSource(args.data, args.seq, args.batch, seed=0)

    tc = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, log_every=10)
    trainer = Trainer(step_fn, state, data, tc, make_global=make_global)
    out = trainer.run()
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"done: step {out['final_step']}, "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
              f"stragglers={out['stragglers']}")
    return out


if __name__ == "__main__":
    main()
