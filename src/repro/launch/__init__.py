"""subpackage."""
