"""Serving driver: batched prefill+decode with optional kNN-LM retrieval.

Demo (CPU)::

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 8 --prompt-len 32 --gen 16 --knn
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--knn", action="store_true", help="enable kNN-LM")
    ap.add_argument("--search-backend", default="auto",
                    choices=["auto", "scan", "kernel", "brute"],
                    help="SearchEngine backend for the datastore "
                         "(sharded needs a mesh launcher, not this driver)")
    ap.add_argument("--lmbda", type=float, default=0.25)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    from repro.configs import ARCHS, smoke_config
    from repro.models import model_fns, synthetic_batch
    from repro.serve.engine import Engine
    from repro.serve.knnlm import KNNDatastore

    cfg = smoke_config(args.arch) if args.smoke else ARCHS[args.arch]
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))

    knn = None
    if args.knn:
        corpus = [synthetic_batch(cfg, 4, args.prompt_len, seed=s)
                  for s in range(4)]
        t0 = time.perf_counter()
        knn = KNNDatastore.from_corpus(fns, params, corpus, cfg.vocab, k=8,
                                       n_pivots=8, block_size=64,
                                       backend=args.search_backend)
        print(f"datastore: {knn.index.db.shape[0]} keys, "
              f"backend={knn.engine.backend_name} "
              f"({time.perf_counter() - t0:.1f}s to build)")

    eng = Engine(fns, params, max_seq=args.prompt_len + args.gen + 8,
                 knn=knn, lmbda=args.lmbda)
    batch = synthetic_batch(cfg, args.requests, args.prompt_len, seed=42)

    t0 = time.perf_counter()
    cache, clen, _ = eng.prefill(batch)
    t_prefill = time.perf_counter() - t0
    t0 = time.perf_counter()
    toks, _ = eng.decode(cache, clen, batch["tokens"][:, -1:], args.gen,
                         temperature=args.temperature)
    t_decode = time.perf_counter() - t0

    n_prompt = args.requests * args.prompt_len
    n_gen = args.requests * args.gen
    print(f"prefill: {n_prompt} tokens in {t_prefill:.2f}s "
          f"({n_prompt / t_prefill:.0f} tok/s)")
    print(f"decode:  {n_gen} tokens in {t_decode:.2f}s "
          f"({n_gen / t_decode:.0f} tok/s, knn={'on' if knn else 'off'})")
    print("sample generations (token ids):")
    for r in range(min(4, args.requests)):
        print(f"  req{r}: {np.asarray(toks[r]).tolist()}")
    return toks


if __name__ == "__main__":
    main()
