import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax fixes the device
# count at first initialization, and the production meshes below need 256
# (single pod) / 512 (2 pods) placeholder host devices.

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (lowering fails on spec mismatches),
  * it fits: ``compiled.memory_analysis()`` per-device bytes,
  * the cost terms for §Roofline: ``compiled.cost_analysis()`` FLOPs/bytes
    and the collective bytes parsed from the post-SPMD HLO text.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh pod            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json.  Pass
``--unrolled-probe`` to additionally lower a pattern-length unrolled model
for exact per-layer cost attribution (scan bodies are counted once by XLA's
cost analysis; the roofline script rescales using the probe).
"""
import argparse
import json
import math
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, applicable, input_specs, model_kind
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.config import ModelConfig
from repro.models.registry import model_fns
from repro.optim import adamw
from repro.train.train_step import make_loss_fn

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# per-arch microbatch accumulation for train_4k (fits HBM; hillclimbed later)
TRAIN_ACCUM = {
    "qwen2-72b": 8, "mixtral-8x22b": 8, "qwen2.5-14b": 4,
}


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------

_sanitize = shd.sanitize


def param_shardings(abstract, mesh: Mesh):
    def leaf(path, x):
        spec = shd.param_spec(path, x.shape)
        return NamedSharding(mesh, _sanitize(spec, x.shape, mesh))
    return jax.tree_util.tree_map_with_path(leaf, abstract)


def batch_shardings(abstract, mesh: Mesh, dp_axes):
    def leaf(x):
        spec = P(dp_axes) if (x.ndim >= 1 and x.shape[0] % _dp_size(mesh, dp_axes) == 0) else P()
        return NamedSharding(mesh, spec)
    return jax.tree.map(leaf, abstract)


def _dp_size(mesh, dp_axes):
    return math.prod(mesh.shape[a] for a in dp_axes)


def cache_shardings(abstract, mesh: Mesh, dp_axes):
    """KV caches: batch -> data axes, heads dim -> model (when divisible).

    Caches under a scanned run carry a leading layer axis, so attn caches
    are [L, B, S, KV, dh] (or [B, S, KV, dh] unstacked) and the SSM/RWKV
    states are [L, B, H, ...] / [B, H, ...]; handle both ranks.
    """
    def leaf(path, x):
        name = shd.path_name(path)
        dims = [None] * x.ndim
        # locate (batch, sharded-feature) axes from the TRAILING structure,
        # which is invariant to the optional leading layer-stack axis:
        if ("/k" in name or "/v" in name or "cross_" in name) and x.ndim >= 4:
            b_ax, f_ax = x.ndim - 4, x.ndim - 2          # [., B, S, KV, dh]
        elif ("ssm_state" in name or "wkv_state" in name) and x.ndim >= 4:
            b_ax, f_ax = x.ndim - 4, x.ndim - 3          # [., B, H, ., .]
        elif x.ndim >= 3:                                # conv/shift [., B, ., C]
            b_ax, f_ax = x.ndim - 3, None
        else:
            b_ax, f_ax = 0, None
        if x.shape[b_ax] % _dp_size(mesh, dp_axes) == 0:
            dims[b_ax] = dp_axes
        if f_ax is not None:
            dims[f_ax] = ("model",)
        spec = _sanitize(P(*dims), x.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf, abstract)


# ---------------------------------------------------------------------------
# collective parsing
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*((?:[a-z0-9_]+\[[^\]]*\](?:,\s*)?)+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|s32|u32|s64|pred|u16)\[([\d,]*)\]")

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
          "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "pred": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum RESULT-shape bytes of every collective op, by kind.

    Counts sync ops (``all-gather(``) and async starts (``all-gather-start``,
    whose result tuple is (operand-alias, destination) — only the LAST tuple
    element is payload); ``-done`` ops are aliases and are skipped.
    """
    out = {}
    for line in hlo_text.splitlines():
        m = re.search(
            r"= ((?:\()?[a-z0-9_]+\[[^=]*?) (all-gather|all-reduce|"
            r"reduce-scatter|all-to-all|collective-permute)(-start)?\(", line)
        if not m:
            continue
        kind = m.group(2)
        shapes = []
        for dm in _SHAPE_RE.finditer(m.group(1)):
            dims = [int(d) for d in dm.group(2).split(",") if d]
            shapes.append(_BYTES[dm.group(1)] * int(np.prod(dims))
                          if dims else _BYTES[dm.group(1)])
        if not shapes:
            continue
        # async start: (alias, dest) tuple -> dest only; sync: single shape
        nbytes = shapes[-1]
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def prepare_cfg(arch: str, shape_name: str, mesh: Mesh, *,
                unrolled: bool = False, unroll_mult: int = 1) -> ModelConfig:
    cfg = ARCHS[arch]
    tp = mesh.shape.get("model", 1)
    # KV replication: smallest rep with (kv*rep) % tp == 0 that still divides
    # the query-head group structure (kv*rep must divide n_heads); rep=1
    # (replicated-KV sharding fallback) when impossible (whisper, internvl).
    rep = 1
    group = cfg.n_heads // cfg.n_kv_heads
    for cand in range(1, group + 1):
        if group % cand == 0 and (cfg.n_kv_heads * cand) % tp == 0:
            rep = cand
            break
    kw = dict(kv_repeat=rep)
    if bool(int(os.environ.get("REPRO_HEAD_PAD", "0"))) and (
            cfg.n_heads % tp or (cfg.n_kv_heads * rep) % tp):
        # q-group padding search (§Perf.S2): smallest padded group g' with
        # kv*g' % tp == 0 and a rep | g' making the KV cache shardable too
        for g2 in range(group, 4 * group + 1):
            if (cfg.n_kv_heads * g2) % tp:
                continue
            reps = [r for r in range(1, g2 + 1)
                    if g2 % r == 0 and (cfg.n_kv_heads * r) % tp == 0]
            if reps:
                kw["q_group_pad"] = g2
                kw["kv_repeat"] = reps[0]
                break
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        kw["max_seq_len"] = shape.seq
    else:
        kw["max_seq_len"] = shape.seq
    if unrolled:
        kw["use_scan"] = False
        kw["n_layers"] = len(cfg.block_pattern) * unroll_mult
        if cfg.encoder_layers:
            kw["encoder_layers"] = unroll_mult
    return cfg.replace(**kw)


def lower_cell(arch: str, shape_name: str, mesh: Mesh, *,
               unrolled: bool = False, unroll_mult: int = 1,
               compile_: bool = True) -> dict:
    cfg = prepare_cfg(arch, shape_name, mesh, unrolled=unrolled,
                      unroll_mult=unroll_mult)
    shape = SHAPES[shape_name]
    fns = model_fns(cfg)
    multi_pod = "pod" in mesh.axis_names
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    # serving mode (REPRO_SERVE_BF16=1): bf16 TP-resident weights, no FSDP —
    # decode must not all-gather parameter shards every token (§Perf.S1)
    serve_bf16 = (shape.kind != "train"
                  and bool(int(os.environ.get("REPRO_SERVE_BF16", "0"))))
    pure_dp = bool(int(os.environ.get("REPRO_PURE_DP", "0")))
    rules = shd.default_rules(multi_pod=multi_pod, fsdp=not serve_bf16,
                              pure_dp=pure_dp)
    shd.set_rules(mesh, rules)
    key = jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    t0 = time.time()
    try:
        if True:  # all shardings are explicit NamedShardings; no mesh context
            abstract_params = jax.eval_shape(fns.init, key)
            if serve_bf16:
                abstract_params = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
                    if (x.dtype == jnp.float32 and len(x.shape) >= 2) else x,
                    abstract_params)
            p_sh = param_shardings(abstract_params, mesh)

            if shape.kind == "train":
                accum = 1 if unrolled else TRAIN_ACCUM.get(arch, 1)
                # bf16 parameter storage (fp32 master in the optimizer):
                # halves FSDP gather + gradient traffic at the source
                bf16_params = bool(int(os.environ.get(
                    "REPRO_TRAIN_BF16_PARAMS", "0")))
                if bf16_params:
                    abstract_params = jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
                        if (x.dtype == jnp.float32 and len(x.shape) >= 2) else x,
                        abstract_params)
                    p_sh = param_shardings(abstract_params, mesh)
                loss_fn = make_loss_fn(
                    fns, cfg, cast_bf16=bool(int(os.environ.get(
                        "REPRO_CAST_BF16", "0"))))

                def train_step(params, opt_m, batch):
                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, batch)
                    if bool(int(os.environ.get("REPRO_BF16_GRAD_REDUCE", "0"))):
                        # bf16 gradient synchronization (standard at fleet
                        # scale; int8+EF in optim/compression.py goes 4x):
                        # halves the dominant backward all-reduce payload
                        grads = jax.tree.map(
                            lambda g: g.astype(jnp.bfloat16), grads)
                    # force gradients onto the parameter sharding: XLA then
                    # reduce-scatters the DP sync instead of all-reducing and
                    # keeping full-size gradient buffers alive
                    grads = jax.tree.map(
                        lambda g, s: jax.lax.with_sharding_constraint(g, s),
                        grads, p_sh)
                    # fused AdamW-style update keeps the lowering honest about
                    # optimizer memory/flops without the full adamw tree code
                    new_m = jax.tree.map(lambda m, g: 0.9 * m + g.astype(jnp.float32),
                                         opt_m, grads)
                    new_p = jax.tree.map(
                        lambda p, m: (p.astype(jnp.float32) - 1e-4 * m).astype(p.dtype),
                        params, new_m)
                    return new_p, new_m, loss

                if accum > 1:
                    b = specs["tokens"].shape[0]
                    specs = {k: jax.ShapeDtypeStruct(
                        (v.shape[0] // accum,) + v.shape[1:], v.dtype)
                        for k, v in specs.items()}
                abstract_m = jax.eval_shape(
                    lambda p: jax.tree.map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), p),
                    abstract_params)
                m_sh = jax.tree.map(
                    lambda s: s, p_sh)  # moments share param sharding
                b_sh = batch_shardings(specs, mesh, dp_axes)
                fn = jax.jit(train_step,
                             in_shardings=(p_sh, m_sh, b_sh),
                             out_shardings=(p_sh, m_sh, NamedSharding(mesh, P())),
                             donate_argnums=(0, 1))
                lowered = fn.lower(abstract_params, abstract_m, specs)
            elif shape.kind == "prefill":
                def prefill(params, batch):
                    hidden, _, _ = fns.forward(params, batch)
                    logits = fns.lm_head(params, hidden[:, -1:])
                    return logits

                b_sh = batch_shardings(specs, mesh, dp_axes)
                fn = jax.jit(prefill, in_shardings=(p_sh, b_sh),
                             out_shardings=NamedSharding(mesh, P()))
                lowered = fn.lower(abstract_params, specs)
            else:  # decode
                bsz = shape.batch
                abstract_cache = jax.eval_shape(
                    lambda p, b: fns.cache_init(p, b, bsz, shape.seq),
                    abstract_params, _abstract_frames(cfg, bsz))
                c_sh = cache_shardings(abstract_cache, mesh, dp_axes)

                def decode(params, cache, tokens, cache_len):
                    hidden, new_cache = fns.decode_step(params, tokens, cache,
                                                        cache_len)
                    logits = fns.lm_head(params, hidden)
                    return logits, new_cache

                tok_spec = jax.ShapeDtypeStruct((bsz, 1), jnp.int32)
                b_sh = batch_shardings({"t": tok_spec}, mesh, dp_axes)["t"]
                fn = jax.jit(decode,
                             in_shardings=(p_sh, c_sh, b_sh, NamedSharding(mesh, P())),
                             out_shardings=(NamedSharding(mesh, P()), c_sh),
                             donate_argnums=(1,))
                lowered = fn.lower(abstract_params, abstract_cache, tok_spec,
                                   jax.ShapeDtypeStruct((), jnp.int32))

            result = {
                "arch": arch, "shape": shape_name,
                "mesh": dict(mesh.shape), "unrolled": unrolled,
                "lower_s": round(time.time() - t0, 1),
                "kv_repeat": cfg.kv_repeat,
                "params": int(cfg.param_count()),
                "active_params": int(cfg.active_param_count()),
            }
            if compile_:
                t1 = time.time()
                compiled = lowered.compile()
                result["compile_s"] = round(time.time() - t1, 1)
                mem = compiled.memory_analysis()
                result["memory"] = {
                    "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                    "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                    "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                    "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
                }
                try:
                    ca = compiled.cost_analysis()
                    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
                    result["cost"] = {k: float(v) for k, v in ca.items()
                                      if isinstance(v, (int, float)) and (
                                          "flops" in k or "bytes" in k or k in ("utilization",))}
                except Exception as e:  # cost analysis is best-effort on CPU
                    result["cost"] = {"error": str(e)}
                hlo = compiled.as_text()
                result["collectives"] = collective_bytes(hlo)
                result["hlo_lines"] = hlo.count("\n")
            return result
    finally:
        shd.set_rules(None, None)


def _abstract_frames(cfg, bsz):
    from repro.models.vlm import VIT_WIDTH
    kind = model_kind(cfg)
    batch = {"tokens": jax.ShapeDtypeStruct((bsz, 1), jnp.int32)}
    if kind == "whisper":
        batch["frames"] = jax.ShapeDtypeStruct(
            (bsz, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if kind == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (bsz, cfg.vision_seq, VIT_WIDTH), jnp.bfloat16)
    return batch


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_cell(arch, shape_name, mesh_kind, *, unrolled_probe=False,
             out_dir=OUT_DIR):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    cfg = ARCHS[arch]
    ok, reason = applicable(cfg, SHAPES[shape_name])
    cell_dir = os.path.join(out_dir, mesh_kind)
    os.makedirs(cell_dir, exist_ok=True)
    path = os.path.join(cell_dir, f"{arch}__{shape_name}.json")
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": dict(mesh.shape),
               "skipped": True, "reason": reason}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"SKIP  {arch} x {shape_name} [{mesh_kind}]: {reason}")
        return rec
    try:
        rec = lower_cell(arch, shape_name, mesh)
        if unrolled_probe:
            rec["probe"] = lower_cell(arch, shape_name, mesh, unrolled=True,
                                      unroll_mult=1)
            rec["probe2"] = lower_cell(arch, shape_name, mesh, unrolled=True,
                                       unroll_mult=2)
        status = "OK"
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": dict(mesh.shape),
               "error": "".join(traceback.format_exception_only(e)).strip(),
               "traceback": traceback.format_exc()[-4000:]}
        status = "FAIL"
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    extra = ""
    if "memory" in rec:
        per_dev = (rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"])
        extra = (f" mem/dev={per_dev/2**30:.2f}GiB "
                 f"compile={rec.get('compile_s')}s "
                 f"coll={sum(v['bytes'] for v in rec.get('collectives', {}).values())/2**20:.0f}MiB")
    print(f"{status:4s}  {arch} x {shape_name} [{mesh_kind}]{extra}", flush=True)
    return rec


def refresh_probes(arch, shape_name, mesh_kind, out_dir=OUT_DIR):
    """Re-lower only the unrolled probes of an existing cell record."""
    path = os.path.join(out_dir, mesh_kind, f"{arch}__{shape_name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    if rec.get("skipped") or "error" in rec:
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    try:
        rec["probe"] = lower_cell(arch, shape_name, mesh, unrolled=True,
                                  unroll_mult=1)
        rec["probe2"] = lower_cell(arch, shape_name, mesh, unrolled=True,
                                   unroll_mult=2)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"PROBE {arch} x {shape_name} [{mesh_kind}] refreshed", flush=True)
    except Exception as e:
        print(f"PROBE-FAIL {arch} x {shape_name}: {e}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--unrolled-probe", action="store_true")
    ap.add_argument("--probes-only", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args(argv)

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                if args.probes_only:
                    refresh_probes(arch, shape_name, mesh_kind, args.out)
                    continue
                rec = run_cell(arch, shape_name, mesh_kind,
                               unrolled_probe=args.unrolled_probe,
                               out_dir=args.out)
                n_fail += 1 if "error" in rec else 0
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
