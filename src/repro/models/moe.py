"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch.

Two execution modes share one math path (``_moe_tokens``):

* **local / auto-sharded** — under ``pjit`` with sharding constraints; the
  dispatch is gather/scatter along the token axis.  Used on a single device
  and in unit tests.
* **manual (shard_map)** — the production path (``moe_apply_sharded``):
  tokens are device-local (batch sharded over ``pod``/``data``), expert
  weights are tensor-parallel on ``d_ff`` over ``model``, and the only
  collective is ONE ``psum`` over ``model`` per layer — the same pattern as
  a dense TP MLP, so MoE adds no new collective phases.  When
  ``expert_parallel`` rules are active (n_experts %% TP == 0, e.g.
  granite-moe's 32 experts), the expert dim shards instead and the dispatch
  adds an ``all_to_all`` (see ``moe_apply_ep``).

Capacity: each expert takes at most ``C = ceil(T * top_k * cf / E)`` tokens
(per device shard); overflow tokens fall back to their residual stream
(standard token-dropping semantics — GShard/Switch).  The router and its
softmax run in fp32; an auxiliary load-balancing loss is returned.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import Array
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import dense_init


def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    d, f, e = cfg.d_model, cfg.d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    glu = cfg.mlp_kind in ("swiglu", "geglu")
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "experts": {
            "up": dense_init(ks[1], (e, d, f), cfg.p_dtype),
            "down": dense_init(ks[2], (e, f, d), cfg.p_dtype),
        },
    }
    if glu:
        p["experts"]["gate"] = dense_init(ks[3], (e, d, f), cfg.p_dtype)
    return p


def _capacity(t: int, m: MoEConfig) -> int:
    return max(1, math.ceil(t * m.top_k * m.capacity_factor / m.n_experts))


def _moe_tokens(p, x: Array, cfg: ModelConfig, *, psum_axis=None,
                no_drop: bool = False):
    """Core MoE on a flat token batch x: [T, D] -> ([T, D], aux_loss).

    All dispatch ops are plain gathers/scatters on the local token axis.
    If ``psum_axis`` is given (shard_map mode, d_ff sharded), the expert
    output partial-sums are reduced over it.
    """
    m = cfg.moe
    T, D = x.shape
    E, K = m.n_experts, m.top_k
    # no_drop (decode): capacity == T guarantees zero token drops, so cached
    # decoding is exactly consistent with teacher forcing.
    C = T if no_drop else _capacity(T, m)

    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, K)                            # [T, K]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch): E * sum_e f_e * p_e --------
    me = probs.mean(0)                                                  # [E]
    assign = jnp.zeros((E,), jnp.float32).at[gate_e.reshape(-1)].add(
        jnp.ones((T * K,), jnp.float32))
    fe = assign / (T * K)
    aux = E * jnp.sum(fe * me)

    # ---- sort-based capacity dispatch ---------------------------------
    flat_e = gate_e.reshape(-1)                                         # [T*K]
    order = jnp.argsort(flat_e, stable=True)                            # [T*K]
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                             # [E]
    seg_start = jnp.cumsum(counts) - counts                             # [E]
    pos_in_e = jnp.arange(T * K) - seg_start[sorted_e]
    keep = pos_in_e < C
    buf_slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)          # overflow -> trash row
    token_of = order // K                                               # [T*K]

    xbuf = jnp.zeros((E * C + 1, D), x.dtype).at[buf_slot].set(x[token_of])
    xbuf = xbuf[: E * C].reshape(E, C, D)

    # ---- expert computation (batched over E) ---------------------------
    up = jnp.einsum("ecd,edf->ecf", xbuf, p["experts"]["up"].astype(x.dtype))
    if "gate" in p["experts"]:
        g = jnp.einsum("ecd,edf->ecf", xbuf, p["experts"]["gate"].astype(x.dtype))
        act = jax.nn.silu(g) if cfg.mlp_kind == "swiglu" else jax.nn.gelu(g)
        hidden = act * up
    else:
        hidden = jax.nn.gelu(up)
    ybuf = jnp.einsum("ecf,efd->ecd", hidden, p["experts"]["down"].astype(x.dtype))
    if psum_axis is not None:
        ybuf = jax.lax.psum(ybuf, psum_axis)                            # TP reduce

    # ---- combine back ---------------------------------------------------
    yflat = jnp.concatenate([ybuf.reshape(E * C, D),
                             jnp.zeros((1, D), ybuf.dtype)], 0)
    contrib = yflat[jnp.where(keep, buf_slot, E * C)]                   # [T*K, D]
    w = (gate_w.reshape(-1)[order] * keep).astype(contrib.dtype)        # dropped -> 0
    out = jnp.zeros((T, D), contrib.dtype).at[token_of].add(contrib * w[:, None])
    return out.astype(x.dtype), aux


def moe_apply(p, x: Array, cfg: ModelConfig, *, no_drop: bool = False):
    """[B, S, D] -> ([B, S, D], aux).  Chooses manual/auto path by context."""
    B, S, D = x.shape
    # the manual path assumes expert weights are ffn-TP'd over "model";
    # under pure-DP rules (expert_ffn unmapped) the auto path is correct
    if shd.active() and shd.rule("expert_ffn"):
        mesh = shd.get_mesh()
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_size = math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1
        if B % dp_size == 0:
            return _moe_sharded(p, x, cfg, no_drop=no_drop)
        # tiny decode batches (B < DP): tokens replicate; let the auto
        # partitioner shard the expert einsums on d_ff (shard_map with
        # unused manual axes trips an XLA SPMD copy bug here)
    y, aux = _moe_tokens(p, x.reshape(B * S, D), cfg, no_drop=no_drop)
    return y.reshape(B, S, D), aux


def _moe_sharded(p, x: Array, cfg: ModelConfig, *, no_drop: bool = False):
    """shard_map wrapper: tokens local per (pod, data) shard, d_ff TP."""
    mesh = shd.get_mesh()
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = ("model",) if "model" in mesh.axis_names else ()
    manual = set(dp_axes) | set(tp)

    ew_spec = {"up": P(None, None, "model"), "down": P(None, "model", None)}
    if "gate" in p["experts"]:
        ew_spec["gate"] = P(None, None, "model")
    in_specs = (
        {"router": P(None, None), "experts": ew_spec},
        P(dp_axes, None, None),
    )

    def local(p_, x_):
        B, S, D = x_.shape
        y, aux = _moe_tokens(p_, x_.reshape(B * S, D), cfg, no_drop=no_drop,
                             psum_axis="model" if tp else None)
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return y.reshape(B, S, D), aux

    from repro.dist.compat import shard_map
    fn = shard_map(
        local, mesh=mesh, in_specs=in_specs,
        out_specs=(P(dp_axes, None, None), P()),
        axis_names=manual, check_vma=False,
    )
    return fn(p, x)
