"""Generic decoder LM over heterogeneous block stacks.

Consecutive layers of the same block type form a *run*; a run of length > 1
is executed with ``lax.scan`` over stacked parameters (``cfg.use_scan``),
which keeps the HLO size O(#distinct runs) — this is what makes 80-layer
models lowerable/compilable in minutes instead of hours, and it is also the
standard production trick for fast compile at scale.  ``cfg.remat`` wraps
each layer body in ``jax.checkpoint`` so the 32k-sequence cells fit HBM.

Zamba2-style ``shared_attn`` blocks are weight-tied: one parameter set at
the top level, applied at every occurrence, consuming ``concat(x, x0)``
where x0 is the embedding-stream output (arXiv:2411.15242).

The forward pass returns final *hidden states*; logits are produced by
:func:`lm_head_apply` (the trainer uses a chunked cross-entropy that never
materializes [B, S, V] — see ``repro/train/losses.py``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist import sharding as shd
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (attn_apply, attn_init, dense_init, mlp_apply,
                                 mlp_init, norm_apply, norm_init)


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------

def _block_init(key, btype: str, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    if btype in ("attn", "moe"):
        p = {
            "ln1": norm_init(cfg),
            "attn": attn_init(ks[0], cfg),
            "ln2": norm_init(cfg),
        }
        if btype == "attn":
            p["mlp"] = mlp_init(ks[1], cfg)
        else:
            p["moe"] = moe_mod.moe_init(ks[1], cfg)
        return p
    if btype == "mamba2":
        return {"ln1": norm_init(cfg), "ssm": ssm_mod.mamba2_init(ks[0], cfg)}
    if btype == "rwkv6":
        return {"rwkv": rwkv_mod.rwkv6_init(ks[0], cfg)}
    raise ValueError(f"unknown block type {btype!r}")


def _block_apply(btype: str, p, x: Array, cfg: ModelConfig, *,
                 cache=None, cache_len=None):
    """Returns (x_out, new_cache, aux_loss)."""
    # Pin the remat-saved residual to the activation dtype: without the
    # barrier XLA hoists the backward's f32 converts into the saved stack
    # (f32[L,B,S,D] instead of bf16 -> 2x residual memory; observed on the
    # qwen2-72b train_4k dry-run, EXPERIMENTS.md §Perf).
    if cache is None:
        from repro.dist.compat import optimization_barrier
        x = optimization_barrier(x)
    aux = jnp.zeros((), jnp.float32)
    if btype in ("attn", "moe"):
        h = norm_apply(p["ln1"], x, cfg)
        a, new_attn_cache = attn_apply(
            p["attn"], h, cfg, cache=None if cache is None else cache["attn"],
            cache_len=cache_len)
        x = x + a
        h2 = norm_apply(p["ln2"], x, cfg)
        if btype == "attn":
            x = x + mlp_apply(p["mlp"], h2, cfg)
        else:
            y, aux = moe_mod.moe_apply(p["moe"], h2, cfg,
                                       no_drop=cache is not None)
            x = x + y
        new_cache = None if cache is None else {"attn": new_attn_cache}
        return x, new_cache, aux
    if btype == "mamba2":
        h = norm_apply(p["ln1"], x, cfg)
        y, new_ssm = ssm_mod.mamba2_apply(
            p["ssm"], h, cfg, cache=None if cache is None else cache["ssm"])
        new_cache = None if cache is None else {"ssm": new_ssm}
        return x + y, new_cache, aux
    if btype == "rwkv6":
        y, new_rw = rwkv_mod.rwkv6_apply(
            p["rwkv"], x, cfg, cache=None if cache is None else cache["rwkv"])
        new_cache = None if cache is None else {"rwkv": new_rw}
        return y, new_cache, aux   # residuals are internal to RWKV blocks
    raise ValueError(btype)


def _block_cache_init(btype: str, cfg: ModelConfig, batch: int, max_seq: int):
    if btype in ("attn", "moe", "shared_attn"):
        kv, dh = cfg.n_kv_heads * cfg.kv_repeat, cfg.head_dim
        if cfg.sliding_window is not None:
            max_seq = min(max_seq, cfg.sliding_window)   # rolling SWA buffer
        return {"attn": {
            "k": jnp.zeros((batch, max_seq, kv, dh), cfg.act_dtype),
            "v": jnp.zeros((batch, max_seq, kv, dh), cfg.act_dtype),
        }}
    if btype == "mamba2":
        return {"ssm": ssm_mod.mamba2_cache_init(cfg, batch)}
    if btype == "rwkv6":
        return {"rwkv": rwkv_mod.rwkv6_cache_init(cfg, batch)}
    raise ValueError(btype)


# ---------------------------------------------------------------------------
# shared (weight-tied) attention block — Zamba2
# ---------------------------------------------------------------------------

def _shared_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    return {
        "in_proj": dense_init(ks[0], (2 * d, d), cfg.p_dtype),
        "ln1": norm_init(cfg),
        "attn": attn_init(ks[1], cfg),
        "ln2": norm_init(cfg),
        "mlp": mlp_init(ks[2], cfg),
        "out_proj": dense_init(ks[3], (d, d), cfg.p_dtype),
    }


def _shared_apply(p, x, x0, cfg, *, cache=None, cache_len=None):
    u = jnp.concatenate([x, x0], axis=-1) @ p["in_proj"].astype(x.dtype)
    a, new_attn = attn_apply(
        p["attn"], norm_apply(p["ln1"], u, cfg), cfg,
        cache=None if cache is None else cache["attn"], cache_len=cache_len)
    u = u + a
    u = u + mlp_apply(p["mlp"], norm_apply(p["ln2"], u, cfg), cfg)
    y = u @ p["out_proj"].astype(x.dtype)
    return x + y, None if cache is None else {"attn": new_attn}


# ---------------------------------------------------------------------------
# the LM
# ---------------------------------------------------------------------------

def _runs(cfg: ModelConfig):
    """Group layer types into (type, count) runs."""
    runs = []
    for t in cfg.layer_types:
        if runs and runs[-1][0] == t and t != "shared_attn":
            runs[-1][1] += 1
        else:
            runs.append([t, 1])
    return [(t, c) for t, c in runs]


def lm_init(key, cfg: ModelConfig) -> dict:
    ks = iter(jax.random.split(key, 4 + 2 * len(_runs(cfg))))
    params: dict[str, Any] = {
        "embed": {"table": (jax.random.normal(next(ks), (cfg.vocab, cfg.d_model))
                            * 0.02).astype(cfg.p_dtype)},
        "final_norm": norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(next(ks), (cfg.d_model, cfg.vocab),
                                             cfg.p_dtype)}
    blocks = []
    for btype, count in _runs(cfg):
        if btype == "shared_attn":
            blocks.append({})  # weight-tied; stored once below
            continue
        if count > 1 and cfg.use_scan:
            kk = jax.random.split(next(ks), count)
            stacked = jax.vmap(lambda k: _block_init(k, btype, cfg))(kk)
            blocks.append(stacked)
        else:
            kk = jax.random.split(next(ks), count)
            blocks.append([_block_init(k, btype, cfg) for k in kk])
    params["blocks"] = blocks
    if "shared_attn" in cfg.layer_types:
        params["shared"] = _shared_init(next(ks), cfg)
    return params


def lm_forward(
    params,
    tokens: Array,
    cfg: ModelConfig,
    *,
    extra_embeds: Array | None = None,
    cache: list | None = None,
    cache_len: Array | int | None = None,
):
    """tokens [B, S] -> (hidden [B, S', D], new_cache, aux_loss).

    ``extra_embeds`` [B, Sv, D] (vision/audio prefix) is prepended;
    S' = Sv + S.  ``cache``/``cache_len`` select the decode path.
    """
    x = params["embed"]["table"].astype(cfg.act_dtype)[tokens]
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cfg.act_dtype), x], axis=1)
    x = shd.shard(x, "batch", None, "model_embed")
    x0 = x
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: list | None = None if cache is None else []

    li = 0     # layer index (for cache bookkeeping)
    for ri, (btype, count) in enumerate(_runs(cfg)):
        if btype == "shared_attn":
            x, nc = _shared_apply(
                params["shared"], x, x0, cfg,
                cache=None if cache is None else cache[ri],
                cache_len=cache_len)
            if new_cache is not None:
                new_cache.append(nc)
            li += 1
            continue
        bp = params["blocks"][ri]
        if count > 1 and cfg.use_scan:
            run_cache = None if cache is None else cache[ri]

            def body(carry, xs):
                h, aux_acc = carry
                layer_p, layer_c = xs
                h, nc, aux = _block_apply(btype, layer_p, h, cfg,
                                          cache=layer_c, cache_len=cache_len)
                return (h, aux_acc + aux), nc

            body_fn = jax.checkpoint(body) if cfg.remat else body
            if run_cache is None:
                (x, aux_total), _ = jax.lax.scan(
                    body_fn, (x, aux_total), (bp, None))
            else:
                (x, aux_total), nc = jax.lax.scan(
                    body_fn, (x, aux_total), (bp, run_cache))
                new_cache.append(nc)
        else:
            ncs = []

            def apply_one(p_, x_, cache_=None, cache_len_=None,
                          _btype=btype):
                return _block_apply(_btype, p_, x_, cfg, cache=cache_,
                                    cache_len=cache_len_)

            fn = jax.checkpoint(apply_one) if cfg.remat else apply_one
            for j in range(count):
                layer_c = None if cache is None else cache[ri][j]
                x, nc, aux = fn(bp[j], x, layer_c, cache_len)
                aux_total = aux_total + aux
                ncs.append(nc)
            if new_cache is not None:
                new_cache.append(ncs)
        li += count

    x = norm_apply(params["final_norm"], x, cfg)
    return x, new_cache, aux_total


def lm_head_apply(params, hidden: Array, cfg: ModelConfig) -> Array:
    """hidden [B, S, D] -> logits [B, S, V] (fp32)."""
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(cfg.act_dtype).T
    else:
        w = params["lm_head"]["w"].astype(cfg.act_dtype)
    logits = hidden @ w
    logits = shd.shard(logits, "batch", None, "vocab")
    return logits.astype(jnp.float32)


def lm_cache_init(cfg: ModelConfig, batch: int, max_seq: int):
    """Per-run cache pytree matching lm_forward's expectations."""
    cache = []
    for btype, count in _runs(cfg):
        if btype == "shared_attn":
            cache.append(_block_cache_init("shared_attn", cfg, batch, max_seq))
        elif count > 1 and cfg.use_scan:
            one = _block_cache_init(btype, cfg, batch, max_seq)
            cache.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape).copy(), one))
        else:
            cache.append([_block_cache_init(btype, cfg, batch, max_seq)
                          for _ in range(count)])
    return cache


def embed_hidden(params, hidden: Array, cfg: ModelConfig) -> Array:
    """Unit-normalized retrieval embedding of final hidden states [B, S, D].

    This is the hook the kNN-LM datastore uses (DESIGN.md §4) — the paper's
    search subsystem consumes exactly these vectors.
    """
    h = hidden.astype(jnp.float32)
    return h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-12)
