"""Shared neural-net layers: norms, RoPE, GQA flash attention, GLU MLPs.

Functional style throughout: ``<layer>_init(key, cfg, ...) -> params`` and
``<layer>_apply(params, x, ...) -> y`` with params as plain dicts of arrays —
``jax.eval_shape``-friendly so the dry-run never allocates real weights.

Attention is a chunked online-softmax ("flash") implementation in pure JAX:
memory stays O(chunk_q * chunk_k) per head regardless of sequence length,
which is what lets the 32k-token cells lower without materializing S^2
score matrices.  Sliding-window (Mixtral/Zamba2-long) and causal masks are
applied per tile.  Softmax statistics are fp32.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist import sharding as shd
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if len(shape) == 3:  # [d, h, dh] fused head projections
        fan_in = shape[0]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), cfg.p_dtype)}
    if cfg.norm_kind == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.p_dtype)
    return p


def norm_apply(p, x: Array, cfg: ModelConfig) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, dh: int | None = None) -> Array:
    dh = dh or cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    return inv  # [dh/2]


def rope_apply(x: Array, positions: Array, inv_freq: Array) -> Array:
    """x: [..., S, H, Dh]; positions broadcastable to [..., S]."""
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # [..., S, dh/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), cfg.p_dtype),
        "wk": dense_init(ks[1], (d, kv, dh), cfg.p_dtype),
        "wv": dense_init(ks[2], (d, kv, dh), cfg.p_dtype),
        "wo": dense_init(ks[3], (h, dh, d), cfg.p_dtype, scale=1.0 / math.sqrt(h * dh)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), cfg.p_dtype)
        p["bk"] = jnp.zeros((kv, dh), cfg.p_dtype)
        p["bv"] = jnp.zeros((kv, dh), cfg.p_dtype)
    return p


def _tile_mask(q_pos, k_pos, causal: bool, window: int | None):
    """[Q, K] bool mask tile from absolute positions."""
    d = q_pos[:, None] - k_pos[None, :]
    m = jnp.ones(d.shape, bool)
    if causal:
        m &= d >= 0
    if window is not None:
        m &= d < window
    return m


def flash_attention(
    q: Array, k: Array, v: Array, *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    chunk_q: int = 512,
    chunk_k: int = 1024,
    kv_valid: Array | None = None,
) -> Array:
    """Chunked online-softmax attention.

    q: [B, Sq, H, Dh];  k/v: [B, Sk, KV, Dh] with H % KV == 0.
    ``q_offset``: absolute position of q[0] (cross/self decode alignment).
    ``kv_valid``: [B, Sk] bool — masks cache padding.
    Returns [B, Sq, H, Dh] in q.dtype; softmax in fp32.
    """
    B, Sq, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    cq, ck = min(chunk_q, Sq), min(chunk_k, Sk)
    # pad to tile multiples
    pq, pk = (-Sq) % cq, (-Sk) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    kvv = kv_valid
    if pk or kvv is not None:
        base = jnp.ones((B, Sk), bool) if kvv is None else kvv
        kvv = jnp.pad(base, ((0, 0), (0, pk)))
    nq, nk = q.shape[1] // cq, k.shape[1] // ck
    scale = 1.0 / math.sqrt(Dh)

    # double scan: q chunks outer, kv chunks inner — peak intermediate is one
    # [B, cq, H, ck] score tile, independent of S.
    qt = jnp.moveaxis(q.reshape(B, nq, cq, H, Dh), 1, 0)    # [nq,B,cq,H,Dh]
    kt = jnp.moveaxis(k.reshape(B, nk, ck, KV, Dh), 1, 0)   # [nk,B,ck,KV,Dh]
    vt = jnp.moveaxis(v.reshape(B, nk, ck, KV, Dh), 1, 0)
    q_pos = q_offset + jnp.arange(nq * cq).reshape(nq, cq)
    k_pos = jnp.arange(nk * ck).reshape(nk, ck)
    kvv_s = (jnp.moveaxis(kvv.reshape(B, nk, ck), 1, 0)
             if kvv is not None else jnp.ones((nk, B, ck), bool))

    def q_step(_, q_in):
        qc, qp = q_in                       # [B,cq,H,Dh], [cq]
        qf = qc.astype(jnp.float32)

        def kv_step(carry, kv_in):
            m_run, l_run, acc = carry       # [B,cq,H], [B,cq,H], [B,cq,H,Dh]
            kc, vc, kp, kval = kv_in        # [B,ck,KV,Dh], ..., [ck], [B,ck]
            kg = jnp.repeat(kc, g, axis=2).astype(jnp.float32)
            vg = jnp.repeat(vc, g, axis=2).astype(jnp.float32)
            s = jnp.einsum("bqhd,bkhd->bqhk", qf, kg) * scale
            mask = _tile_mask(qp, kp, causal, window)[None, :, None, :]
            mask = mask & kval[:, None, None, :]
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m_run, s.max(-1))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask, p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isneginf(m_run), -jnp.inf, m_run) - m_safe)
            corr = jnp.where(jnp.isneginf(m_run), 0.0, corr)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum("bqhk,bkhd->bqhd", p, vg)
            return (m_new, l_new, acc), None

        init = (
            jnp.full((B, cq, H), -jnp.inf, jnp.float32),
            jnp.zeros((B, cq, H), jnp.float32),
            jnp.zeros((B, cq, H, Dh), jnp.float32),
        )
        # checkpoint the tile body: backward recomputes the [B,cq,H,ck] score
        # tile instead of storing one per kv step (flash-backward memory law)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), init,
                                      (kt, vt, k_pos, kvv_s))
        out_c = acc / jnp.maximum(l, 1e-30)[..., None]      # [B,cq,H,Dh]
        return None, out_c.astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (qt, q_pos))        # [nq,B,cq,H,Dh]
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * cq, H, Dh)[:, :Sq]
    return out.astype(q.dtype)


def attn_apply(
    p, x: Array, cfg: ModelConfig, *,
    positions: Array | None = None,
    cache: dict | None = None,
    cache_len: Array | None = None,
    kv_override: tuple[Array, Array] | None = None,
    causal: bool = True,
):
    """Self-attention (or cross-attention via ``kv_override``).

    Training/prefill: ``cache=None`` — full-sequence flash attention.
    Decode: ``cache = {"k": [B,Smax,KV,Dh], "v": ...}`` with ``cache_len``
    the number of valid entries; x is [B, 1, D].  Returns (y, new_cache).
    """
    B, S, D = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    inv_freq = rope_freqs(cfg)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    if kv_override is None:
        kx = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        vx = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        if "bk" in p:
            kx = kx + p["bk"].astype(x.dtype)
            vx = vx + p["bv"].astype(x.dtype)
    else:
        kx, vx = kv_override

    if positions is None:
        offset = cache_len if cache_len is not None else 0
        positions = jnp.arange(S) + offset
        positions = jnp.broadcast_to(positions, (B, S))
    q = rope_apply(q, positions, inv_freq)
    if kv_override is None:
        kx = rope_apply(kx, positions, inv_freq)
    g_orig = h // kv
    g_pad = cfg.q_group_pad
    if g_pad is not None and g_pad > g_orig:
        # q-group padding: insert zero q-heads at each KV group's tail so the
        # padded head count shards over TP.  Zero queries attend uniformly to
        # their group's values, but those outputs are SLICED OFF below before
        # wo — outputs are bit-identical to the unpadded model (tested).
        qg = q.reshape(B, S, kv, g_orig, cfg.head_dim)
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, g_pad - g_orig), (0, 0)))
        q = qg.reshape(B, S, kv * g_pad, cfg.head_dim)
    if cfg.kv_repeat > 1:
        # Megatron-style KV replication: make the KV head count divisible by
        # the TP degree (params stay at n_kv_heads -> checkpoint compatible).
        kx = jnp.repeat(kx, cfg.kv_repeat, axis=2)
        vx = jnp.repeat(vx, cfg.kv_repeat, axis=2)

    q = shd.shard(q, "batch", None, "heads", None)
    kx = shd.shard(kx, "batch", None, "kv_heads", None)
    vx = shd.shard(vx, "batch", None, "kv_heads", None)

    new_cache = cache
    if cache is not None:
        idx = cache_len  # scalar
        Smax = cache["k"].shape[1]
        ring = (cfg.sliding_window is not None and Smax == cfg.sliding_window
                and S == 1)
        if ring:
            # rolling SWA buffer: slot = t mod W; every live slot is inside
            # the window by construction, RoPE was baked at write time, so
            # masking reduces to "slot is filled".
            write_at = jnp.mod(idx, Smax)
            kvalid = jnp.broadcast_to(
                jnp.arange(Smax)[None, :] < jnp.minimum(idx + 1, Smax), (B, Smax))
            causal, window, q_off = False, None, 0
        else:
            write_at = idx
            # causal across the cache: q row t attends to kv <= idx + t (and
            # within the window); S == 1 (decode) and S > 1 (cache-filling
            # prefill) both route through q_offset.
            kvalid = jnp.broadcast_to(
                jnp.arange(Smax)[None, :] < (idx + S), (B, Smax))
            causal, window, q_off = True, cfg.sliding_window, idx
        ck = jax.lax.dynamic_update_slice(cache["k"], kx.astype(cache["k"].dtype),
                                          (0, write_at, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vx.astype(cache["v"].dtype),
                                          (0, write_at, 0, 0))
        new_cache = {"k": ck, "v": cv}
        out = flash_attention(
            q, ck.astype(x.dtype), cv.astype(x.dtype),
            causal=causal, window=window, q_offset=q_off,
            kv_valid=kvalid,
            chunk_q=min(max(S, 8), cfg.attn_chunk_q), chunk_k=cfg.attn_chunk_k,
        )
    else:
        out = flash_attention(
            q, kx, vx, causal=causal, window=cfg.sliding_window,
            chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k,
        )
    if g_pad is not None and g_pad > g_orig:
        out = out.reshape(B, S, kv, g_pad, cfg.head_dim)[:, :, :, :g_orig]
        out = out.reshape(B, S, h, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    y = shd.shard(y, "batch", None, "model_embed")
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP (GLU family)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    glu = cfg.mlp_kind in ("swiglu", "geglu")
    p = {
        "w_up": dense_init(ks[0], (d, f), cfg.p_dtype),
        "w_down": dense_init(ks[1], (f, d), cfg.p_dtype),
    }
    if glu:
        p["w_gate"] = dense_init(ks[2], (d, f), cfg.p_dtype)
    return p


def mlp_apply(p, x: Array, cfg: ModelConfig) -> Array:
    up = x @ p["w_up"].astype(x.dtype)
    up = shd.shard(up, "batch", None, "ffn")
    if cfg.mlp_kind == "swiglu":
        g = x @ p["w_gate"].astype(x.dtype)
        hidden = jax.nn.silu(g) * up
    elif cfg.mlp_kind == "geglu":
        g = x @ p["w_gate"].astype(x.dtype)
        hidden = jax.nn.gelu(g) * up
    else:
        hidden = jax.nn.gelu(up)
    y = hidden @ p["w_down"].astype(x.dtype)
    return shd.shard(y, "batch", None, "model_embed")
