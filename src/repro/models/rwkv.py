"""RWKV-6 ("Finch") block: data-dependent decay linear attention.

Per head (key/value dim M = d_model / n_heads), with data-dependent
per-channel decay w_t in (0,1) and bonus u:

    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T            S: [M, M]

Token-shift mixing and the low-rank (LoRA) data-dependent interpolation
follow arXiv:2404.05892.  The sequential path is a ``lax.scan`` over time;
``rwkv6_chunked`` is the O(S/Q) chunked form used for long sequences
(identical output, tested) — the TPU-friendly variant with matmul-dominated
inner loops.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist import sharding as shd
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, norm_init, norm_apply

LORA_R = 32     # decay LoRA rank
MIX_R = 32      # token-shift mix LoRA rank


def _n_heads(cfg: ModelConfig):
    return cfg.n_heads


def _head_norm(p, x: Array, h: int) -> Array:
    """Per-head RMS normalization (RWKV's GroupNorm(n_heads), scale-only).

    Head-local: no cross-head reduction, so a head-sharded layout flows
    through without collectives (EXPERIMENTS.md §Perf.P2).
    """
    B, S, D = x.shape
    m = D // h
    xf = x.astype(jnp.float32).reshape(B, S, h, m)
    var = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6)
    y = y * p["scale"].astype(jnp.float32).reshape(h, m)
    return y.reshape(B, S, D).astype(x.dtype)


def rwkv6_init(key, cfg: ModelConfig):
    d = cfg.d_model
    h = _n_heads(cfg)
    m = d // h
    ks = jax.random.split(key, 16)
    p = {
        # token-shift static mixes (5 for time-mix: r,k,v,g,w)
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(cfg.p_dtype),
        "mix_w1": dense_init(ks[1], (d, 5 * MIX_R), cfg.p_dtype),
        "mix_w2": dense_init(ks[2], (5, MIX_R, d), cfg.p_dtype, scale=0.01),
        "wr": dense_init(ks[3], (d, d), cfg.p_dtype),
        "wk": dense_init(ks[4], (d, d), cfg.p_dtype),
        "wv": dense_init(ks[5], (d, d), cfg.p_dtype),
        "wg": dense_init(ks[6], (d, d), cfg.p_dtype),
        "wo": dense_init(ks[7], (d, d), cfg.p_dtype, scale=1.0 / math.sqrt(d)),
        # decay: w = exp(-exp(w0 + lora(xw)))
        "w0": (jax.random.uniform(ks[8], (d,)) * 2.0 - 6.0).astype(jnp.float32),
        "decay_w1": dense_init(ks[9], (d, LORA_R), cfg.p_dtype),
        "decay_w2": dense_init(ks[10], (LORA_R, d), cfg.p_dtype, scale=0.01),
        "u": (jax.random.uniform(ks[11], (h, m)) - 0.5).astype(jnp.float32),
        "ln_x": norm_init(cfg, d),   # per-head group norm approximated by LN
        # channel-mix
        "cm_mu": (jax.random.uniform(ks[12], (2, d)) * 0.5 + 0.25).astype(cfg.p_dtype),
        "cm_k": dense_init(ks[13], (d, cfg.d_ff), cfg.p_dtype),
        "cm_v": dense_init(ks[14], (cfg.d_ff, d), cfg.p_dtype),
        "cm_r": dense_init(ks[15], (d, d), cfg.p_dtype),
        # pre-norms for the two sub-blocks
        "ln1": norm_init(cfg, d),
        "ln2": norm_init(cfg, d),
    }
    return p


def _wkv_scan(r, k, v, w, u, state):
    """Sequential recurrence.  r,k,v: [B,S,H,M]; w: [B,S,H,M] decay in (0,1);
    u: [H,M]; state: [B,H,M,M] (key dim first).  Returns (out, new_state)."""
    def step(s, inp):
        rt, kt, vt, wt = inp                                # [B,H,M] each
        kv = kt[..., :, None] * vt[..., None, :]            # [B,H,M,M]
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, out = jax.lax.scan(step, state, seq)
    return jnp.moveaxis(out, 0, 1), state                   # [B,S,H,M]


def _wkv_chunked(r, k, v, w, u, state, chunk: int = 64):
    """Chunked equivalent of :func:`_wkv_scan` (matmul-dominated).

    Within a chunk of length Q: decay products D_t = prod_{i<=t} w_i let the
    intra-chunk term become a masked (r D_t / D_j) k_j^T matmul; the carried
    state contributes r_t D_t S.  fp32 throughout; w is clamped away from 0.
    """
    B, S, H, M = r.shape
    pad = (-S) % chunk
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    L = r.shape[1] // chunk
    rc = jnp.moveaxis(r.reshape(B, L, chunk, H, M), 1, 0).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(B, L, chunk, H, M), 1, 0).astype(jnp.float32)
    vc = jnp.moveaxis(v.reshape(B, L, chunk, H, M), 1, 0).astype(jnp.float32)
    wc = jnp.moveaxis(w.reshape(B, L, chunk, H, M), 1, 0).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)   # strict lower

    def step(s, inp):
        rq, kq, vq, wq = inp                                # [B,Q,H,M]
        logw = jnp.log(jnp.clip(wq, 1e-6, 1.0))
        cum = jnp.cumsum(logw, axis=1)                      # log D_t (incl. t)
        # intra-chunk (j < t): A[t,j] = r_t . (D_{t-1} / D_j) k_j
        r_d = rq * jnp.exp(cum - logw)                      # r_t D_{t-1}
        k_d = kq * jnp.exp(-cum)                            # k_j / D_j
        att = jnp.einsum("bqhm,bjhm->bhqj", r_d, k_d)
        att = jnp.where(mask[None, None], att, 0.0)
        y = jnp.einsum("bhqj,bjhm->bqhm", att, vq)
        # bonus diagonal: u * (r_t . k_t) v_t
        y = y + jnp.einsum("bqhm,bqhm->bqh", rq, u[None, None] * kq)[..., None] * vq
        # carried state: r_t D_{t-1}... state is pre-chunk S
        y = y + jnp.einsum("bqhk,bhkv->bqhv", r_d, s)
        # new state: S' = D_Q S + sum_j (D_Q/D_j) k_j v_j
        k_end = kq * jnp.exp(cum[:, -1:] - cum)
        s = s * jnp.exp(cum[:, -1])[..., None] + jnp.einsum(
            "bjhk,bjhv->bhkv", k_end, vq)
        return s, y

    state, ys = jax.lax.scan(step, state.astype(jnp.float32), (rc, kc, vc, wc))
    out = jnp.moveaxis(ys, 0, 1).reshape(B, -1, H, M)[:, :S]
    return out, state


def rwkv6_apply(p, x: Array, cfg: ModelConfig, *, cache: dict | None = None,
                chunked: bool | None = None):
    """Time-mix + channel-mix (residuals internal).  x: [B,S,D] -> (y, cache).

    The returned y is the full block output — the LM wrapper must NOT add
    another residual around this block.
    """
    B, S, D = x.shape
    h = _n_heads(cfg)
    m = D // h
    if chunked is None:
        chunked = S >= 256

    # ---- time-mix ------------------------------------------------------
    xin = norm_apply(p["ln1"], x, cfg)
    last_tm = cache["shift_tm"].astype(xin.dtype) if cache else jnp.zeros((B, 1, D), xin.dtype)
    sx = jnp.concatenate([last_tm, xin[:, :-1]], axis=1) - xin  # shifted minus x
    base = xin + sx * p["mu"][0].astype(xin.dtype)
    lora = jnp.tanh(base @ p["mix_w1"].astype(xin.dtype))   # [B,S,5R]
    lora = lora.reshape(B, S, 5, MIX_R)
    # per-branch deltas: computing the five [B,S,D] mixes one at a time keeps
    # the peak intermediate at 1x activation size — the fused
    # einsum('bstr,trd->bstd') materialized a 5*D tensor that dominated both
    # HBM traffic and the TP collectives (§Perf.P2, -2.5 GiB x4 per layer).
    w2 = p["mix_w2"].astype(xin.dtype)                      # [5, R, D]
    mu = p["mu"].astype(xin.dtype)                          # [5, D]

    def _mix(i):
        delta = lora[:, :, i] @ w2[i]                       # [B,S,D]
        return xin + sx * (mu[i] + delta)

    xr, xk, xv, xg, xw = (_mix(i) for i in range(5))

    # head-sharded token mixer: r/k/v/w/out all stay sharded on the head
    # axis (wr/wk/wv outputs are TP-sharded); the per-head norm keeps it so,
    # and the single psum hides inside the wo projection (input-sharded).
    hs = lambda t: shd.shard(t, "batch", None, "heads", None)
    r = hs((xr @ p["wr"].astype(x.dtype)).reshape(B, S, h, m))
    k = hs((xk @ p["wk"].astype(x.dtype)).reshape(B, S, h, m))
    v = hs((xv @ p["wv"].astype(x.dtype)).reshape(B, S, h, m))
    g = shd.shard(jax.nn.silu(xg @ p["wg"].astype(x.dtype)), "batch", None, "ffn")
    dec = p["w0"] + (jnp.tanh(xw @ p["decay_w1"].astype(x.dtype))
                     @ p["decay_w2"].astype(x.dtype)).astype(jnp.float32)
    w = hs(jnp.exp(-jnp.exp(dec)).reshape(B, S, h, m))      # (0,1)

    state = (cache["wkv_state"] if cache
             else jnp.zeros((B, h, m, m), jnp.float32))
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    if chunked and S > 1:
        out, new_state = _wkv_chunked(rf, kf, vf, w, p["u"], state)
    else:
        out, new_state = _wkv_scan(rf, kf, vf, w, p["u"], state)
    out = _head_norm(p["ln_x"], out.reshape(B, S, D), h).astype(x.dtype) * g
    out = shd.shard(out, "batch", None, "ffn")
    y_tm = out @ p["wo"].astype(x.dtype)
    y_tm = shd.shard(y_tm, "batch", None, "model_embed")
    x = x + y_tm

    # ---- channel-mix ---------------------------------------------------
    xc = norm_apply(p["ln2"], x, cfg)
    last_cm = cache["shift_cm"].astype(xc.dtype) if cache else jnp.zeros((B, 1, D), xc.dtype)
    sx2 = jnp.concatenate([last_cm, xc[:, :-1]], axis=1) - xc
    xk2 = xc + sx2 * p["cm_mu"][0].astype(xc.dtype)
    xr2 = xc + sx2 * p["cm_mu"][1].astype(xc.dtype)
    kk = jnp.square(jax.nn.relu(xk2 @ p["cm_k"].astype(x.dtype)))
    kk = shd.shard(kk, "batch", None, "ffn")
    cmix = jax.nn.sigmoid(xr2 @ p["cm_r"].astype(x.dtype)) * (
        kk @ p["cm_v"].astype(x.dtype))
    y = x + shd.shard(cmix, "batch", None, "model_embed")

    new_cache = None
    if cache is not None:
        new_cache = {
            "shift_tm": xin[:, -1:],   # last time-mix INPUT token
            "shift_cm": xc[:, -1:],    # last channel-mix INPUT token
            "wkv_state": new_state,
        }
    return y, new_cache


def rwkv6_cache_init(cfg: ModelConfig, batch: int):
    d, h = cfg.d_model, _n_heads(cfg)
    m = d // h
    return {
        "shift_tm": jnp.zeros((batch, 1, d), cfg.act_dtype),
        "shift_cm": jnp.zeros((batch, 1, d), cfg.act_dtype),
        "wkv_state": jnp.zeros((batch, h, m, m), jnp.float32),
    }
