"""InternVL2-style VLM backbone (vision frontend is a STUB).

Per the assignment the ViT is not modeled: ``input_specs`` provides
precomputed patch embeddings [B, n_patches, D_vit].  What is real here is
the InternVL "connector": pixel-shuffle-equivalent MLP projector from the
ViT width into the LM's d_model, followed by the full language model with
the vision tokens prepended (loss is masked to text positions by the
trainer).
"""
from __future__ import annotations

import jax
from jax import Array

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, norm_init, norm_apply
from repro.models.lm import lm_forward, lm_init

VIT_WIDTH = 1024   # InternViT-300M output width (stub frontend)


def vlm_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    params = lm_init(k1, cfg)
    params["projector"] = {
        "ln": norm_init(cfg, VIT_WIDTH),
        "w1": dense_init(k2, (VIT_WIDTH, cfg.d_model), cfg.p_dtype),
        "w2": dense_init(k3, (cfg.d_model, cfg.d_model), cfg.p_dtype),
    }
    return params


def project_patches(params, patches: Array, cfg: ModelConfig) -> Array:
    """[B, Sv, VIT_WIDTH] -> [B, Sv, d_model]."""
    h = norm_apply(params["projector"]["ln"], patches.astype(cfg.act_dtype), cfg)
    h = jax.nn.gelu(h @ params["projector"]["w1"].astype(h.dtype))
    return h @ params["projector"]["w2"].astype(h.dtype)


def vlm_forward(params, patches: Array, tokens: Array, cfg: ModelConfig,
                **kw):
    """-> (hidden [B, Sv+St, D], cache, aux)."""
    vis = project_patches(params, patches, cfg)
    return lm_forward(params, tokens, cfg, extra_embeds=vis, **kw)
