"""Mamba-2 (SSD) block — chunked scan formulation (arXiv:2405.21060).

State-space recurrence per head (state N, head dim P):

    h_t = exp(A * dt_t) h_{t-1} + dt_t * B_t x_t^T        h: [N, P]
    y_t = C_t h_t + D x_t

computed with the SSD chunk decomposition: intra-chunk quadratic term
(attention-like, MXU-friendly) + inter-chunk recurrence over chunk states
via ``lax.scan``.  Parallel/train path and single-token decode path share
parameters; decode carries ``{"ssm_state": [B,H,N,P], "conv_state": ...}``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist import sharding as shd
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, norm_init, norm_apply


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    return s, d_in, n_heads, conv_dim


def mamba2_init(key, cfg: ModelConfig):
    s, d_in, nh, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_in + 2 * s.n_groups * s.state_dim + nh  # z, x, B, C, dt
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,)) *
                 (math.log(s.dt_max) - math.log(s.dt_min)) + math.log(s.dt_min))
    p = {
        "in_proj": dense_init(ks[0], (d, proj_out), cfg.p_dtype),
        "out_proj": dense_init(ks[1], (d_in, d), cfg.p_dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv softplus
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_w": dense_init(ks[3], (s.conv_width, conv_dim), cfg.p_dtype,
                             scale=1.0 / math.sqrt(s.conv_width)),
        "conv_b": jnp.zeros((conv_dim,), cfg.p_dtype),
        "gate_norm": norm_init(cfg, d_in),
    }
    return p


def _causal_conv(x: Array, w: Array, b: Array, state: Array | None):
    """Depthwise causal conv1d.  x: [B, S, C], w: [W, C] -> [B, S, C].

    ``state``: [B, W-1, C] carries the tail for decode; returns new state.
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # [B, S+W-1, C]
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return out + b.astype(x.dtype), new_state


def _ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """SSD scan.  x:[b,s,h,p] dt:[b,s,h] A:[h] B,C:[b,s,g,n] -> y:[b,s,h,p].

    fp32 state math; returns (y, final_state [b,h,n,p]).
    """
    b, s_len, h, p_dim = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-s_len) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = x.shape[1] // chunk
    # scan over chunks: all quadratic intermediates stay one-chunk sized
    # ([b, q, q, h] etc.), so memory is O(chunk^2) regardless of S.
    xc = jnp.moveaxis(x.reshape(b, L, chunk, h, p_dim), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(b, L, chunk, h), 1, 0)
    Bc = jnp.moveaxis(B.reshape(b, L, chunk, g, n), 1, 0)
    Cc = jnp.moveaxis(C.reshape(b, L, chunk, g, n), 1, 0)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, inp):
        xq, dtq, Bq, Cq = inp                               # per-chunk slices
        xq = xq.astype(jnp.float32)
        dtq = dtq.astype(jnp.float32)
        Bq = jnp.repeat(Bq, rep, axis=2).astype(jnp.float32)   # [b,q,h,n]
        Cq = jnp.repeat(Cq, rep, axis=2).astype(jnp.float32)
        dA = dtq * A[None, None, :]                         # [b,q,h] (negative)
        cum = jnp.cumsum(dA, axis=1)
        # intra: y[t] = sum_{j<=t} exp(a_t - a_j) (C_t . B_j) dt_j x_j
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # [b,q,j,h]
        decay = jnp.where(mask[None, :, :, None], decay, 0.0)
        CB = jnp.einsum("bqhn,bjhn->bqjh", Cq, Bq)
        y_intra = jnp.einsum("bqjh,bjhp->bqhp", CB * decay * dtq[:, None], xq)
        # inter: y += C_t exp(a_t) H_prev
        y_inter = jnp.einsum("bqhn,bqh,bhnp->bqhp", Cq, jnp.exp(cum), carry)
        # new chunk state
        seg = jnp.exp(cum[:, -1:, :] - cum) * dtq           # [b,q,h]
        state = jnp.einsum("bqh,bqhn,bqhp->bhnp", seg, Bq, xq)
        new = carry * jnp.exp(cum[:, -1])[..., None, None] + state
        return new, y_intra + y_inter

    init = (jnp.zeros((b, h, n, p_dim), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final, ys = jax.lax.scan(step, init, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, -1, h, p_dim)[:, :s_len]
    return y, final


def mamba2_apply(p, x: Array, cfg: ModelConfig, *, cache: dict | None = None):
    """x: [B, S, D].  Train/prefill when cache is None; else one-step decode.

    Returns (y, new_cache).
    """
    s, d_in, nh, conv_dim = _dims(cfg)
    B_, S_, D_ = x.shape
    proj = x @ p["in_proj"].astype(x.dtype)                 # [B,S,*]
    z, xin, Bc, Cc, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + s.n_groups * s.state_dim,
               2 * d_in + 2 * s.n_groups * s.state_dim], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_state = cache.get("conv_state") if cache else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :d_in]
    Bc = conv_out[..., d_in : d_in + s.n_groups * s.state_dim]
    Cc = conv_out[..., d_in + s.n_groups * s.state_dim :]

    heads_x = xin.reshape(B_, S_, nh, s.head_dim)
    Bh = Bc.reshape(B_, S_, s.n_groups, s.state_dim)
    Ch = Cc.reshape(B_, S_, s.n_groups, s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # [B,S,nh]
    A = -jnp.exp(p["A_log"])                                           # [nh]

    if cache is None:
        heads_x = shd.shard(heads_x, "batch", None, "heads", None)
        y, final = _ssd_chunked(heads_x, dt, A, Bh, Ch, s.chunk)
        new_state = final
    elif S_ > 4:
        # cache-filling prefill: chunked path from the carried state
        y, new_state = _ssd_chunked(heads_x, dt, A, Bh, Ch, s.chunk,
                                    init_state=cache["ssm_state"])
    else:
        # recurrent single (or few) token update
        st = cache["ssm_state"].astype(jnp.float32)         # [B,nh,N,P]
        rep = nh // s.n_groups
        Bh_ = jnp.repeat(Bh, rep, axis=2).astype(jnp.float32)
        Ch_ = jnp.repeat(Ch, rep, axis=2).astype(jnp.float32)
        xf = heads_x.astype(jnp.float32)
        ys = []
        for t in range(S_):                                 # S_ is 1 in decode
            dA = jnp.exp(dt[:, t] * A[None, :])             # [B,nh]
            st = st * dA[..., None, None] + jnp.einsum(
                "bhn,bhp,bh->bhnp", Bh_[:, t], xf[:, t], dt[:, t])
            ys.append(jnp.einsum("bhn,bhnp->bhp", Ch_[:, t], st))
        y = jnp.stack(ys, axis=1)                           # [B,S,nh,P]
        new_state = st

    y = y + xf_d(heads_x) * p["D"][None, None, :, None]
    y = y.reshape(B_, S_, d_in).astype(x.dtype)
    y = norm_apply(p["gate_norm"], y * jax.nn.silu(z), cfg)
    out = y @ p["out_proj"].astype(x.dtype)
    out = shd.shard(out, "batch", None, "model_embed")
    new_cache = {"ssm_state": new_state, "conv_state": new_conv} if (
        cache is not None) else None
    return out, new_cache


def xf_d(h):
    return h.astype(jnp.float32)


def mamba2_cache_init(cfg: ModelConfig, batch: int):
    s, d_in, nh, conv_dim = _dims(cfg)
    return {
        "ssm_state": jnp.zeros((batch, nh, s.state_dim, s.head_dim), jnp.float32),
        "conv_state": jnp.zeros((batch, s.conv_width - 1, conv_dim), jnp.float32),
    }
