"""Model configuration — one dataclass covers every assigned architecture.

Blocks are described by a per-layer pattern so heterogeneous (hybrid) stacks
are first-class: ``block_pattern`` is a list of block-type strings of length
``n_layers`` (or a short form that is tiled).  Supported block types:

  "attn"     GQA self-attention (+ optional sliding window) + MLP
  "moe"      GQA self-attention + mixture-of-experts MLP
  "mamba2"   Mamba-2 (SSD) block
  "rwkv6"    RWKV-6 time-mix + channel-mix block
  "shared_attn"  Zamba2-style block: weight-TIED attention+MLP (one shared
                 set of weights applied at several depths)

Encoder–decoder (whisper) and vision-prefix (internvl2) variants are handled
by the model wrappers in :mod:`repro.models.whisper` / ``vlm`` on top of the
same decoder stack.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64          # N
    head_dim: int = 64           # P
    expand: int = 2              # d_inner = expand * d_model
    n_groups: int = 1            # B/C groups (GVA)
    chunk: int = 256             # SSD chunk length
    conv_width: int = 4          # local conv kernel size
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int | None = None           # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    block_pattern: tuple[str, ...] = ("attn",)   # tiled to n_layers
    mlp_kind: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm_kind: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    qkv_bias: bool = False
    sliding_window: int | None = None   # tokens; None = full attention
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    shared_attn_every: int = 6          # zamba2: shared block cadence
    max_seq_len: int = 4096
    # --- numerics / execution ---
    kv_repeat: int = 1                  # replicate KV heads so TP divides them
    q_group_pad: int | None = None      # pad q heads per KV group to this
                                        # (zero heads -> zero outputs; lets
                                        # awkward head counts shard over TP)
    dtype: str = "bfloat16"             # activation dtype
    param_dtype: str = "float32"
    use_scan: bool = True               # scan over homogeneous layer runs
    remat: bool = True                  # activation checkpoint each layer
    attn_chunk_q: int = 512             # flash-attention tile sizes
    attn_chunk_k: int = 1024
    logits_chunk: int = 512             # chunked cross-entropy span
    # encoder-decoder / multimodal frontends (stubs provide embeddings)
    encoder_layers: int = 0             # whisper: encoder depth
    encoder_seq: int = 0                # whisper: #frames (e.g. 1500)
    vision_seq: int = 0                 # internvl2: #patch embeddings

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def layer_types(self) -> tuple[str, ...]:
        pat = self.block_pattern
        reps = -(-self.n_layers // len(pat))
        return tuple((pat * reps)[: self.n_layers])

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter / FLOP accounting (for roofline §) ----------
    def param_count(self) -> int:
        d, h, kv, dh, f, v = (self.d_model, self.n_heads, self.n_kv_heads,
                              self.head_dim, self.d_ff, self.vocab)
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        glu = self.mlp_kind in ("swiglu", "geglu")
        mlp = d * f * (3 if glu else 2)
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        for t in self.layer_types:
            if t == "attn":
                n += attn + mlp
            elif t == "moe":
                m = self.moe or MoEConfig()
                n += attn + m.n_experts * mlp + d * m.n_experts
            elif t == "mamba2":
                s = self.ssm or SSMConfig()
                di = s.expand * d
                nh = di // s.head_dim
                n += d * (2 * di + 2 * s.n_groups * s.state_dim + nh) + di * d + di
            elif t == "rwkv6":
                # time-mix: r,k,v,g,o + decay MLPs; channel-mix: 2 mats
                n += 5 * d * d + 2 * d * self.d_ff + self.d_ff * d
            elif t == "shared_attn":
                pass  # weight-tied; counted once below
        if "shared_attn" in self.layer_types:
            n += attn + mlp + 2 * d * d  # shared block + in/out projections
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        glu = self.mlp_kind in ("swiglu", "geglu")
        mlp = d * f * (3 if glu else 2)
        dead = sum(
            (self.moe.n_experts - self.moe.top_k) * mlp
            for t in self.layer_types if t == "moe"
        )
        return self.param_count() - dead
