"""Whisper-style encoder–decoder backbone (audio frontend is a STUB).

Per the assignment, the conv/mel frontend is not modeled: ``input_specs``
provides precomputed frame embeddings [B, n_frames, D] (n_frames = 1500 for
whisper-small's 30 s window).  The transformer backbone is real:

  encoder: bidirectional attention blocks over frames
  decoder: causal self-attention + cross-attention to encoder output + MLP

Decode shapes lower the decoder step with a self-attn KV cache plus the
precomputed cross-attention K/V (computed once from the encoder output at
prefill, reused every step — the standard enc-dec serving layout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist import sharding as shd
from repro.models.config import ModelConfig
from repro.models.layers import (attn_apply, attn_init, mlp_apply, mlp_init,
                                 norm_apply, norm_init)


def whisper_init(key, cfg: ModelConfig) -> dict:
    ks = iter(jax.random.split(key, 6))
    enc_layer = lambda k: {
        "ln1": norm_init(cfg), "attn": attn_init(jax.random.fold_in(k, 0), cfg),
        "ln2": norm_init(cfg), "mlp": mlp_init(jax.random.fold_in(k, 1), cfg),
    }
    dec_layer = lambda k: {
        "ln1": norm_init(cfg), "self": attn_init(jax.random.fold_in(k, 0), cfg),
        "ln2": norm_init(cfg), "cross": attn_init(jax.random.fold_in(k, 1), cfg),
        "ln3": norm_init(cfg), "mlp": mlp_init(jax.random.fold_in(k, 2), cfg),
    }
    enc_keys = jax.random.split(next(ks), cfg.encoder_layers)
    dec_keys = jax.random.split(next(ks), cfg.n_layers)
    params = {
        "embed": {"table": (jax.random.normal(next(ks), (cfg.vocab, cfg.d_model))
                            * 0.02).astype(cfg.p_dtype)},
        "enc": (jax.vmap(enc_layer)(enc_keys) if cfg.use_scan
                else [enc_layer(k) for k in enc_keys]),
        "dec": (jax.vmap(dec_layer)(dec_keys) if cfg.use_scan
                else [dec_layer(k) for k in dec_keys]),
        "enc_norm": norm_init(cfg),
        "final_norm": norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        from repro.models.layers import dense_init
        params["lm_head"] = {"w": dense_init(next(ks), (cfg.d_model, cfg.vocab),
                                             cfg.p_dtype)}
    return params


def _enc_block(p, x, cfg):
    a, _ = attn_apply(p["attn"], norm_apply(p["ln1"], x, cfg), cfg, causal=False)
    x = x + a
    return x + mlp_apply(p["mlp"], norm_apply(p["ln2"], x, cfg), cfg)


def _dec_block(p, x, enc_kv, cfg, cache=None, cache_len=None):
    a, new_self = attn_apply(
        p["self"], norm_apply(p["ln1"], x, cfg), cfg,
        cache=None if cache is None else cache["self"], cache_len=cache_len)
    x = x + a
    c, _ = attn_apply(
        p["cross"], norm_apply(p["ln2"], x, cfg), cfg,
        kv_override=enc_kv, causal=False, cache_len=cache_len)
    x = x + c
    x = x + mlp_apply(p["mlp"], norm_apply(p["ln3"], x, cfg), cfg)
    return x, None if cache is None else {"self": new_self}


def _cross_kv(p, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output for one layer."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(enc_out.dtype))
    if "bk" in p["cross"]:
        k = k + p["cross"]["bk"].astype(enc_out.dtype)
        v = v + p["cross"]["bv"].astype(enc_out.dtype)
    return k, v


def encode(params, frames: Array, cfg: ModelConfig) -> Array:
    """frames [B, Se, D] -> encoder output [B, Se, D]."""
    x = shd.shard(frames.astype(cfg.act_dtype), "batch", None, "model_embed")
    if cfg.use_scan:
        def body(h, lp):
            return _enc_block(lp, h, cfg), None
        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, params["enc"])
    else:
        for lp in params["enc"]:
            x = _enc_block(lp, x, cfg)
    return norm_apply(params["enc_norm"], x, cfg)


def whisper_forward(params, frames: Array, tokens: Array, cfg: ModelConfig):
    """Teacher-forced training pass -> (hidden [B, St, D], None, aux=0)."""
    enc_out = encode(params, frames, cfg)
    x = params["embed"]["table"].astype(cfg.act_dtype)[tokens]
    x = shd.shard(x, "batch", None, "model_embed")
    if cfg.use_scan:
        def body(h, lp):
            kv = _cross_kv(lp, enc_out, cfg)
            h, _ = _dec_block(lp, h, kv, cfg)
            return h, None
        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, params["dec"])
    else:
        for lp in params["dec"]:
            kv = _cross_kv(lp, enc_out, cfg)
            x, _ = _dec_block(lp, x, kv, cfg)
    x = norm_apply(params["final_norm"], x, cfg)
    return x, None, jnp.zeros((), jnp.float32)


def whisper_cache_init(params, frames: Array, cfg: ModelConfig, batch: int,
                       max_seq: int):
    """Run the encoder once; build {self-attn cache, cross K/V} per layer."""
    enc_out = encode(params, frames, cfg)
    kv, dh = cfg.n_kv_heads * cfg.kv_repeat, cfg.head_dim

    def per_layer(lp):
        ck, cv = _cross_kv(lp, enc_out, cfg)
        return {
            "self": {
                "k": jnp.zeros((batch, max_seq, kv, dh), cfg.act_dtype),
                "v": jnp.zeros((batch, max_seq, kv, dh), cfg.act_dtype),
            },
            "cross_k": ck, "cross_v": cv,
        }

    if cfg.use_scan:
        return jax.vmap(per_layer)(params["dec"])
    return [per_layer(lp) for lp in params["dec"]]


def whisper_decode_step(params, tokens: Array, cfg: ModelConfig, cache,
                        cache_len):
    """tokens [B, 1] -> (hidden [B, 1, D], new_cache)."""
    x = params["embed"]["table"].astype(cfg.act_dtype)[tokens]

    def one(lp, h, lc):
        kv = (lc["cross_k"], lc["cross_v"])
        h, nc = _dec_block(lp, h, kv, cfg, cache=lc, cache_len=cache_len)
        new_lc = dict(lc)
        new_lc["self"] = nc["self"]
        return h, new_lc

    if cfg.use_scan:
        def body(h, xs):
            lp, lc = xs
            h, new_lc = one(lp, h, lc)
            return h, new_lc
        x, new_cache = jax.lax.scan(body, x, (params["dec"], cache))
    else:
        new_cache = []
        for lp, lc in zip(params["dec"], cache):
            x, new_lc = one(lp, x, lc)
            new_cache.append(new_lc)
    x = norm_apply(params["final_norm"], x, cfg)
    return x, new_cache
