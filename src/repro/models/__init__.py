"""Model zoo: generic LM over heterogeneous blocks + enc-dec + VLM wrappers."""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig  # noqa: F401
from repro.models.registry import ModelFns, model_fns, synthetic_batch  # noqa: F401
