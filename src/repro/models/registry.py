"""Uniform model interface over the three model kinds (lm / vlm / whisper).

    fns = model_fns(cfg)
    params = fns.init(key)                       # or jax.eval_shape(fns.init, key)
    hidden, cache, aux = fns.forward(params, batch)       # train/prefill
    cache = fns.cache_init(params, batch, max_seq)        # serving
    hidden, cache = fns.decode_step(params, tokens, cache, cache_len)

``batch`` is a dict: tokens/labels (+ patches | frames for vlm | whisper).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import lm as lm_mod
from repro.models import vlm as vlm_mod
from repro.models import whisper as wh_mod
from repro.models.config import ModelConfig
from repro.configs.shapes import model_kind


@dataclasses.dataclass(frozen=True)
class ModelFns:
    cfg: ModelConfig
    kind: str
    init: Callable[..., Any]
    forward: Callable[..., Any]          # (params, batch) -> (hidden, cache, aux)
    cache_init: Callable[..., Any]       # (params, batch, bsz, max_seq) -> cache
    decode_step: Callable[..., Any]      # (params, tokens, cache, cache_len)
    lm_head: Callable[..., Any]          # (params, hidden) -> logits
    loss_offset: Callable[[dict], int]   # #prefix positions excluded from loss


def model_fns(cfg: ModelConfig) -> ModelFns:
    kind = model_kind(cfg)

    if kind == "lm":
        def fwd(params, batch):
            return lm_mod.lm_forward(params, batch["tokens"], cfg)

        def cache_init(params, batch, bsz, max_seq):
            return lm_mod.lm_cache_init(cfg, bsz, max_seq)

        def decode(params, tokens, cache, cache_len):
            h, nc, _ = lm_mod.lm_forward(params, tokens, cfg, cache=cache,
                                         cache_len=cache_len)
            return h, nc

        return ModelFns(cfg, kind, lambda k: lm_mod.lm_init(k, cfg), fwd,
                        cache_init, decode,
                        lambda p, h: lm_mod.lm_head_apply(p, h, cfg),
                        lambda batch: 0)

    if kind == "vlm":
        def fwd(params, batch):
            return vlm_mod.vlm_forward(params, batch["patches"],
                                       batch["tokens"], cfg)

        def cache_init(params, batch, bsz, max_seq):
            return lm_mod.lm_cache_init(cfg, bsz, max_seq)

        def decode(params, tokens, cache, cache_len):
            h, nc, _ = lm_mod.lm_forward(params, tokens, cfg, cache=cache,
                                         cache_len=cache_len)
            return h, nc

        return ModelFns(cfg, kind, lambda k: vlm_mod.vlm_init(k, cfg), fwd,
                        cache_init, decode,
                        lambda p, h: lm_mod.lm_head_apply(p, h, cfg),
                        lambda batch: cfg.vision_seq)

    if kind == "whisper":
        def fwd(params, batch):
            return wh_mod.whisper_forward(params, batch["frames"],
                                          batch["tokens"], cfg)

        def cache_init(params, batch, bsz, max_seq):
            return wh_mod.whisper_cache_init(params, batch["frames"], cfg,
                                             bsz, max_seq)

        def decode(params, tokens, cache, cache_len):
            return wh_mod.whisper_decode_step(params, tokens, cfg, cache,
                                              cache_len)

        return ModelFns(cfg, kind, lambda k: wh_mod.whisper_init(k, cfg), fwd,
                        cache_init, decode,
                        lambda p, h: lm_mod.lm_head_apply(p, h, cfg),
                        lambda batch: 0)

    raise ValueError(kind)


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    """Concrete random batch matching input_specs (for smoke tests)."""
    from repro.models.vlm import VIT_WIDTH
    kind = model_kind(cfg)
    k = jax.random.PRNGKey(seed)
    kt, kl, kf = jax.random.split(k, 3)
    out = {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(kl, (batch, seq), 0, cfg.vocab, jnp.int32),
    }
    if kind == "vlm":
        out["patches"] = jax.random.normal(kf, (batch, cfg.vision_seq, VIT_WIDTH),
                                           jnp.float32).astype(jnp.bfloat16)
    if kind == "whisper":
        out["frames"] = jax.random.normal(kf, (batch, cfg.encoder_seq, cfg.d_model),
                                          jnp.float32).astype(jnp.bfloat16)
    return out
