"""Gradient compression with error feedback (int8, per-tensor scale).

For cross-pod (DCN) gradient synchronization at 1000+ nodes the all-reduce
payload dominates; int8 quantization cuts it 4x vs fp32 (2x vs bf16).  Error
feedback (Seide et al. / EF-SGD) carries the quantization residual into the
next step so convergence is preserved (property-tested: the error-feedback
accumulator keeps the *running sum* of compressed gradients within O(1) of
the true sum, independent of step count).

Integration point: ``train_step(..., compress_grads=True)`` quantizes the
per-microbatch-accumulated gradient *before* the implicit DP all-reduce by
wrapping the gradient in a quantize->dequantize pair under a
``with_sharding_constraint`` that keeps the int8 payload as the value
crossing the ``pod`` axis (XLA reduces the dequantized values; the dry-run
measures the collective-byte effect of the smaller dtype).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jax.Array):
    """fp -> (int8, scale).  Symmetric per-tensor."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, err):
    """Quantize grads + error-feedback residual.

    Returns (dequantized grads to feed the optimizer/all-reduce, new err).
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), target - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
