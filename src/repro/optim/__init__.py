"""subpackage."""
