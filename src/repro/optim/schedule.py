"""LR schedules (pure functions of the step index)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int,
                  total_steps: int, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / max(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 *
                     (1 + jnp.cos(math.pi * prog)))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, *, peak_lr: float, **_):
    return jnp.full((), peak_lr, jnp.float32)
