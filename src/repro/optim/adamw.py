"""Sharded AdamW with decoupled weight decay, global-norm clipping.

Functional: ``init(params) -> state``; ``update(grads, state, params, lr)
-> (params, state, metrics)``.  Optimizer moments inherit the parameter
sharding (they are tree-mapped from params), so FSDP rules shard them too.
Master weights are fp32; bf16 params are supported by casting on apply.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # parameters whose path matches any of these fragments get NO decay
    no_decay: tuple[str, ...] = ("scale", "bias", "norm", "dt_bias", "A_log",
                                 "D", "w0", "u", "mu")


def _decay_mask(params, cfg: AdamWConfig):
    def leaf(path, _):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        return not any(frag in name for frag in cfg.no_decay)
    return jax.tree_util.tree_map_with_path(leaf, params)


def init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(grads, state, params, lr, cfg: AdamWConfig = AdamWConfig()):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    decay = _decay_mask(params, cfg)

    def upd(g, m, v, p, dec):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + jnp.where(dec, cfg.weight_decay, 0.0) * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_d = tdef.flatten_up_to(decay)
    out = [upd(g, m, v, p, d) for g, m, v, p, d in
           zip(flat_g, flat_m, flat_v, flat_p, flat_d)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"step": step, "m": new_m, "v": new_v}, metrics
