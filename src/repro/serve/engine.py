"""Serving engine: batched prefill + KV-cache decode, optional kNN-LM.

``prefill`` runs the model over the prompt tokens through the cache-filling
path (attention writes K/V as it goes; SSM/RWKV states carry forward), so a
following ``decode`` continues exactly.  Sampling is greedy or temperature;
the kNN-LM hook (the paper's technique in the serving layer) interpolates
next-token distributions with datastore neighbors — see
:mod:`repro.serve.knnlm`.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.lm import lm_forward
from repro.models.registry import ModelFns


class Engine:
    def __init__(self, fns: ModelFns, params, *, max_seq: int,
                 knn: "Any | None" = None, lmbda: float = 0.25):
        self.fns = fns
        self.params = params
        self.cfg = fns.cfg
        self.max_seq = max_seq
        self.knn = knn
        self.lmbda = lmbda
        self._decode_jit = jax.jit(self._decode_step)

    # -------------------------------------------------------------- prefill
    def prefill(self, batch: dict):
        """Prompt batch -> (cache, cache_len, last_hidden [B, D])."""
        bsz = batch["tokens"].shape[0]
        cache = self.fns.cache_init(self.params, batch, bsz, self.max_seq)
        if self.fns.kind == "whisper":
            # encoder ran inside cache_init (cross K/V); feed decoder prompt
            hidden, cache = self.fns.decode_step(
                self.params, batch["tokens"], cache, jnp.int32(0))
        else:
            toks = batch["tokens"]
            if self.fns.kind == "vlm":
                from repro.models.vlm import project_patches
                vis = project_patches(self.params, batch["patches"], self.cfg)
                # vision prefix + prompt go through the cache path together
                hidden, cache, _ = lm_forward(
                    self.params, toks, self.cfg, extra_embeds=vis,
                    cache=cache, cache_len=jnp.int32(0))
                cache_len = jnp.int32(vis.shape[1] + toks.shape[1])
                return cache, cache_len, hidden[:, -1]
            hidden, cache = self.fns.decode_step(
                self.params, toks, cache, jnp.int32(0))
        cache_len = jnp.int32(batch["tokens"].shape[1])
        return cache, cache_len, hidden[:, -1]

    # --------------------------------------------------------------- decode
    def _decode_step(self, params, tokens, cache, cache_len):
        hidden, cache = self.fns.decode_step(params, tokens, cache, cache_len)
        logits = self.fns.lm_head(params, hidden)[:, -1]     # [B, V]
        return hidden[:, -1], logits, cache

    def decode(self, cache, cache_len, first_tokens, n_steps: int, *,
               temperature: float = 0.0, seed: int = 0):
        """Greedy/temperature decode.  Returns (tokens [B, n], new_cache)."""
        toks = first_tokens
        out = []
        key = jax.random.PRNGKey(seed)
        for i in range(n_steps):
            hidden, logits, cache = self._decode_jit(
                self.params, toks, cache, cache_len)
            probs = jax.nn.softmax(logits, axis=-1)
            if self.knn is not None:
                probs = self.knn.interpolate(hidden, probs, self.lmbda)
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, jnp.log(jnp.maximum(probs, 1e-20)) / temperature)
            else:
                nxt = jnp.argmax(probs, axis=-1)
            toks = nxt[:, None].astype(jnp.int32)
            out.append(toks)
            cache_len = cache_len + 1
        return jnp.concatenate(out, axis=1), cache
