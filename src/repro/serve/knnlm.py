"""kNN-LM (Khandelwal et al., ICLR 2020) on the paper's exact search.

Datastore: (unit-normalized final hidden state h_t  ->  next token w_{t+1})
pairs harvested from a corpus pass.  At decode, the current hidden state
queries the datastore for its exact top-k cosine neighbors (block-pruned
search — LSH/IVF recall loss is exactly what the paper's bounds remove),
turns neighbor similarities into a distribution with a temperature softmax,
and interpolates:  p = (1-λ) p_LM + λ p_kNN.

All lookups go through :class:`repro.search.SearchEngine`, so backend
choice (scan / Pallas kernel / mesh-sharded datastore) is engine policy —
pass ``backend=`` (default auto) or a ready-made engine; the old
``use_kernel`` flag is gone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import BlockIndex, build_index
from repro.models.lm import embed_hidden
from repro.search import SearchEngine


class KNNDatastore:
    def __init__(self, index: BlockIndex, values: jnp.ndarray, vocab: int,
                 *, k: int = 16, temp: float = 10.0, backend: str = "auto",
                 engine: SearchEngine | None = None):
        self.engine = engine or SearchEngine(index, backend=backend)
        self.values = values            # [n] int32 next-token ids
        self.vocab = vocab
        self.k = k
        self.temp = temp

    @property
    def index(self) -> BlockIndex:
        return self.engine.index

    # ------------------------------------------------------------ building
    @classmethod
    def from_pairs(cls, embeddings: np.ndarray, next_tokens: np.ndarray,
                   vocab: int, *, n_pivots: int = 16, block_size: int = 128,
                   **kw) -> "KNNDatastore":
        idx = build_index(jnp.asarray(embeddings, jnp.float32),
                          n_pivots=n_pivots, block_size=block_size)
        return cls(idx, jnp.asarray(next_tokens, jnp.int32), vocab, **kw)

    @classmethod
    def from_corpus(cls, fns, params, batches, vocab: int, **kw):
        """Harvest (hidden -> next token) pairs with the model itself."""
        embs, nxt = [], []
        for batch in batches:
            hidden, _, _ = fns.forward(params, batch)
            off = fns.loss_offset(batch)
            h = embed_hidden(params, hidden[:, off:], fns.cfg)
            embs.append(np.asarray(h[:, :-1].reshape(-1, h.shape[-1])))
            nxt.append(np.asarray(batch["tokens"][:, 1:]).reshape(-1))
        return cls.from_pairs(np.concatenate(embs), np.concatenate(nxt),
                              vocab, **kw)

    # ----------------------------------------------------------- inference
    def lookup(self, hidden: jnp.ndarray):
        """hidden [B, D] -> (sims [B,k], token ids [B,k])."""
        sims, ids, _stats = self.engine.search(hidden, self.k)
        toks = jnp.where(ids >= 0, self.values[jnp.maximum(ids, 0)], 0)
        return sims, toks, ids

    def knn_probs(self, hidden: jnp.ndarray) -> jnp.ndarray:
        sims, toks, ids = self.lookup(hidden)
        w = jax.nn.softmax(self.temp * sims, axis=-1)        # [B, k]
        w = jnp.where(ids >= 0, w, 0.0)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        B = hidden.shape[0]
        probs = jnp.zeros((B, self.vocab), jnp.float32)
        probs = probs.at[jnp.arange(B)[:, None], toks].add(w)
        return probs

    def interpolate(self, hidden: jnp.ndarray, lm_probs: jnp.ndarray,
                    lmbda: float) -> jnp.ndarray:
        return (1.0 - lmbda) * lm_probs + lmbda * self.knn_probs(hidden)
