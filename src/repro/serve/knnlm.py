"""kNN-LM (Khandelwal et al., ICLR 2020) on the paper's exact search.

Datastore: (unit-normalized final hidden state h_t  ->  next token w_{t+1})
pairs harvested from a corpus pass.  At decode, the current hidden state
queries the datastore for its exact top-k cosine neighbors (block-pruned
search — LSH/IVF recall loss is exactly what the paper's bounds remove),
turns neighbor similarities into a distribution with a temperature softmax,
and interpolates:  p = (1-λ) p_LM + λ p_kNN.

All lookups go through :class:`repro.search.SearchEngine`, so backend
choice (scan / Pallas kernel / mesh-sharded datastore) is engine policy.
The datastore is a thin value-table wrapper over an engine: construct it
around a ready-made :class:`SearchEngine` (or a bare index, which gets
wrapped), or let :meth:`from_pairs` / :meth:`from_corpus` route through
``SearchEngine.build`` — one build surface for every entry point.

The datastore is *online*: :meth:`add_pairs` appends (hidden, token)
pairs to a live store through the engine's
:class:`~repro.core.online.MutableIndex` handle, :meth:`delete` removes
rows, and :meth:`frontend` wraps the engine in a continuous-batching
:class:`~repro.serve.frontend.ContinuousBatcher` for request-at-a-time
serving.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import BlockIndex
from repro.models.lm import embed_hidden
from repro.search import SearchEngine
from repro.serve.frontend import ContinuousBatcher


class KNNDatastore:
    def __init__(self, index: BlockIndex | SearchEngine,
                 values: jnp.ndarray, vocab: int,
                 *, k: int = 16, temp: float = 10.0, backend: str = "auto",
                 engine: SearchEngine | None = None):
        if engine is not None:
            self.engine = engine
        elif isinstance(index, SearchEngine):
            self.engine = index
        else:
            self.engine = SearchEngine(index, backend=backend)
        self.values = jnp.asarray(values, jnp.int32)  # [n] next-token ids
        self.vocab = vocab
        self.k = k
        self.temp = temp

    @property
    def index(self) -> BlockIndex:
        return self.engine.index

    # ------------------------------------------------------------ building
    @classmethod
    def from_pairs(cls, embeddings: np.ndarray, next_tokens: np.ndarray,
                   vocab: int, *, k: int = 16, temp: float = 10.0,
                   backend: str = "auto", engine: SearchEngine | None = None,
                   **build_kw) -> "KNNDatastore":
        """Build a datastore from raw (embedding, next-token) pairs.

        ``build_kw`` forwards to :meth:`SearchEngine.build` verbatim
        (``n_pivots``, ``block_size``, ``mesh`` / ``distributed=True`` for
        a sharded store, any engine knob) — the datastore has no build
        path of its own.  Pass ``engine=`` to skip the build entirely.
        """
        if engine is None:
            engine = SearchEngine.build(
                jnp.asarray(embeddings, jnp.float32),
                backend=backend, **build_kw)
        return cls(engine, jnp.asarray(next_tokens, jnp.int32), vocab,
                   k=k, temp=temp)

    @classmethod
    def from_corpus(cls, fns, params, batches, vocab: int, **kw):
        """Harvest (hidden -> next token) pairs with the model itself."""
        embs, nxt = [], []
        for batch in batches:
            hidden, _, _ = fns.forward(params, batch)
            off = fns.loss_offset(batch)
            h = embed_hidden(params, hidden[:, off:], fns.cfg)
            embs.append(np.asarray(h[:, :-1].reshape(-1, h.shape[-1])))
            nxt.append(np.asarray(batch["tokens"][:, 1:]).reshape(-1))
        return cls.from_pairs(np.concatenate(embs), np.concatenate(nxt),
                              vocab, **kw)

    # -------------------------------------------------------------- online
    def add_pairs(self, embeddings, next_tokens) -> list[int]:
        """Append (embedding, next-token) pairs to the live store.

        Goes through the engine's online handle
        (:meth:`SearchEngine.online`), so the next :meth:`lookup` sees
        the new rows immediately — no rebuild, no retrace while the
        block budget lasts.  Returns the new rows' external ids, which
        index :attr:`values` directly (ids are append-ordered and stable
        across :meth:`~repro.core.online.MutableIndex.reoptimize`, so
        the value table never needs remapping).  Mutate the store only
        through these methods: a bare ``engine.online().insert`` would
        mint ids the value table does not cover.
        """
        toks = jnp.asarray(next_tokens, jnp.int32).reshape(-1)
        ids = self.engine.online().insert(embeddings)
        if len(ids) != toks.shape[0]:
            raise ValueError(
                f"{len(ids)} embeddings but {toks.shape[0]} next_tokens")
        if ids and ids[0] != self.values.shape[0]:
            raise RuntimeError(
                f"value table has {self.values.shape[0]} rows but the "
                f"engine minted id {ids[0]}; the engine was mutated "
                "outside this datastore")
        self.values = jnp.concatenate([self.values, toks])
        return ids

    def delete(self, ids) -> None:
        """Tombstone-delete rows by external id.  Their value-table rows
        become unreachable (a deleted row can never be returned by
        ``lookup``) and are reclaimed at the next reoptimize."""
        self.engine.online().delete(ids)

    def frontend(self, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0) -> ContinuousBatcher:
        """A continuous-batching front end over this store's engine at
        this store's ``k`` (see :mod:`repro.serve.frontend`)."""
        return ContinuousBatcher(self.engine, self.k, max_batch=max_batch,
                                 max_wait_ms=max_wait_ms)

    # ----------------------------------------------------------- inference
    def lookup(self, hidden: jnp.ndarray):
        """hidden [B, D] -> (sims [B,k], token ids [B,k])."""
        sims, ids, _stats = self.engine.search(hidden, self.k)
        toks = jnp.where(ids >= 0, self.values[jnp.maximum(ids, 0)], 0)
        return sims, toks, ids

    def knn_probs(self, hidden: jnp.ndarray) -> jnp.ndarray:
        sims, toks, ids = self.lookup(hidden)
        w = jax.nn.softmax(self.temp * sims, axis=-1)        # [B, k]
        w = jnp.where(ids >= 0, w, 0.0)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        B = hidden.shape[0]
        probs = jnp.zeros((B, self.vocab), jnp.float32)
        probs = probs.at[jnp.arange(B)[:, None], toks].add(w)
        return probs

    def interpolate(self, hidden: jnp.ndarray, lm_probs: jnp.ndarray,
                    lmbda: float) -> jnp.ndarray:
        return (1.0 - lmbda) * lm_probs + lmbda * self.knn_probs(hidden)
