"""Continuous-batching front end for a live search engine.

Serving traffic arrives one query at a time, but every layer below —
the fused dispatch cache, the Pallas kernel grid, the τ prescan — is
built for batches: a [1, d] search wastes the whole query-tile axis and
pays a full dispatch per request.  :class:`ContinuousBatcher` closes the
gap with the standard continuous-batching loop: concurrent
:meth:`submit` calls land in a queue, a single worker coalesces them
into microbatches bounded by ``max_batch`` (amortization ceiling) and
``max_wait_ms`` (latency floor), runs **one** engine search per
microbatch, and resolves each caller's future with its own row of the
result.

Microbatches are zero-padded to exactly ``max_batch`` rows before the
search, so every dispatch reuses one fused-cache signature
(``SearchStats.retraces == 0`` after the first batch) no matter how many
requests happened to coalesce.  Padding rows cost compute but never
correctness — their results are sliced off before futures resolve.

The engine itself is not thread-safe against concurrent mutation, so the
worker serializes all device work through a single executor thread;
online inserts/deletes (:meth:`SearchEngine.online`) interleave safely
*between* microbatches by going through :meth:`run`, the same
single-thread funnel.
"""
from __future__ import annotations

import asyncio
from concurrent.futures import ThreadPoolExecutor

import numpy as np

__all__ = ["ContinuousBatcher"]


class ContinuousBatcher:
    """Coalesce concurrent single-query searches into engine microbatches.

    Args:
      engine: a :class:`repro.search.SearchEngine` (any single-host
        backend).
      k: top-k depth every submitted query is answered with (one k keeps
        one fused-cache signature).
      max_batch: microbatch width; also the padded batch shape every
        dispatch uses.
      max_wait_ms: how long the worker holds an underfull microbatch open
        for stragglers after the first query arrives.

    Use as an async context manager, or call :meth:`close` explicitly.
    """

    def __init__(self, engine, k: int, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.engine = engine
        self.k = int(k)
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait_ms) / 1e3
        #: microbatches dispatched / queries served (occupancy telemetry)
        self.n_batches = 0
        self.n_queries = 0
        self._queue: asyncio.Queue = asyncio.Queue()
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._worker: asyncio.Task | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._closed = False

    # ------------------------------------------------------------- metrics
    @property
    def occupancy(self) -> float:
        """Mean fraction of each dispatched microbatch that was real
        queries (1.0 = every batch full)."""
        if self.n_batches == 0:
            return 0.0
        return self.n_queries / (self.n_batches * self.max_batch)

    # ----------------------------------------------------------- lifecycle
    async def __aenter__(self) -> "ContinuousBatcher":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def close(self) -> None:
        """Stop the worker after the queue drains; reject new submits."""
        if self._closed:
            return
        self._closed = True
        if self._worker is not None:
            if self._loop is asyncio.get_running_loop():
                await self._queue.join()
                self._worker.cancel()
                try:
                    await self._worker
                except asyncio.CancelledError:
                    pass
            # else: the worker's loop already died (sequential asyncio.run
            # reuse) and took the task with it — nothing left to drain
            self._worker = None
        self._pool.shutdown(wait=True)

    # ------------------------------------------------------------- serving
    async def submit(self, query):
        """Search one query ``[d]``; returns ``(sims [k], ids [k])`` as
        numpy arrays once its microbatch has run."""
        if self._closed:
            raise RuntimeError("batcher is closed")
        q = np.asarray(query, np.float32)
        if q.ndim != 1:
            raise ValueError(f"submit takes one query [d], got {q.shape}")
        loop = asyncio.get_running_loop()
        if self._worker is not None and self._loop is not loop:
            # the worker belongs to another event loop.  If that loop is
            # still running this is genuine cross-loop use — refuse loudly.
            # Otherwise the loop died (the common sequential-asyncio.run
            # reuse): the old worker task and its queue are dead, and a
            # submit enqueued onto them would hang forever — re-create
            # both on the caller's loop (the executor thread is
            # loop-agnostic and keeps the engine serialized throughout).
            if self._loop is not None and self._loop.is_running():
                raise RuntimeError(
                    "batcher is already serving another running event "
                    "loop; one ContinuousBatcher binds to one loop at a "
                    "time")
            self._worker = None
            self._queue = asyncio.Queue()
        if self._worker is None:
            self._loop = loop
            self._worker = loop.create_task(self._run_worker())
        fut = loop.create_future()
        self._queue.put_nowait((q, fut))
        return await fut

    async def run(self, fn, *args):
        """Run ``fn(*args)`` on the batcher's device thread, serialized
        against search dispatches — the safe slot for online mutations
        (``engine.online().insert(...)``) while traffic is live."""
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(self._pool, fn, *args)

    # -------------------------------------------------------------- worker
    async def _run_worker(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            first = await self._queue.get()
            batch = [first]
            deadline = loop.time() + self.max_wait
            while len(batch) < self.max_batch:
                timeout = deadline - loop.time()
                if timeout <= 0 and self._queue.empty():
                    break
                try:
                    batch.append(await asyncio.wait_for(
                        self._queue.get(), max(timeout, 0.0)))
                except asyncio.TimeoutError:
                    break
            b = len(batch)
            q = np.zeros((self.max_batch, batch[0][0].shape[0]), np.float32)
            for i, (qi, _) in enumerate(batch):
                q[i] = qi
            try:
                sims, ids, _stats = await loop.run_in_executor(
                    self._pool, self._search, q)
                self.n_batches += 1
                self.n_queries += b
                for i, (_, fut) in enumerate(batch):
                    if not fut.done():
                        fut.set_result((sims[i], ids[i]))
            except Exception as e:                    # noqa: BLE001
                for _, fut in batch:
                    if not fut.done():
                        fut.set_exception(e)
            finally:
                for _ in batch:
                    self._queue.task_done()

    def _search(self, q: np.ndarray):
        sims, ids, stats = self.engine.search(q, self.k)
        return np.asarray(sims), np.asarray(ids), stats
