"""Serving: kNN-LM datastore, decode engine, continuous-batching front end."""
from repro.serve.frontend import ContinuousBatcher
from repro.serve.knnlm import KNNDatastore

__all__ = ["ContinuousBatcher", "KNNDatastore"]
