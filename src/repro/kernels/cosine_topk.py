"""Pallas kernel: fused block-pruned exact cosine top-k (the paper, on MXU).

One kernel implements the whole search inner loop of
:mod:`repro.core.index`:

  for each query tile i (grid dim 0, parallel):
    for each database tile j (grid dim 1, sequential):
      1. evaluate the Eq. 13 pivot-interval upper bound for tile j   (VPU)
      2. if no query in the tile can beat its running k-th best: SKIP —
         ``@pl.when`` guards the matmul and the top-k merge entirely
      3. else: scores = q_tile @ db_tile.T                           (MXU)
         merge into the running top-k held in VMEM scratch           (VPU)

The running (top_s, top_i) scratch persists across the sequential j steps
(TPU grid iteration order guarantees this); outputs are flushed on the last
j.  The merge uses K unrolled max/argmax extractions — K <= 64 keeps this a
small fraction of the matmul cost at BN >= 256.

On real TPU hardware step 2's win is MXU + VMEM-bandwidth; the HBM->VMEM
copy of a pruned tile can additionally be elided with a scalar-prefetch
index map (planned variant; the copy is sequential-DMA-overlapped anyway).
In this repo the kernel is validated with ``interpret=True`` on CPU.

Alignment: BM, BN multiples of 128 (MXU systolic dims); D <= 4096 is kept
whole in VMEM (q tile + db tile at BM=BN=128, D=4096, f32 = 4 MiB of ~16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 256
_NEG_INF = float("-inf")


def _make_kernel(k: int, bm: int, bn: int, margin: float, prune: bool,
                 element_stats: bool, use_cap: bool = False):
    def kernel(order_ref, nvalid_ref, tau_ref, qn_ref, db_ref, qp_ref,
               lo_ref, hi_ref, rv_ref, *rest):
        if use_cap:
            cap_ref, rest = rest[0], rest[1:]
        if element_stats:
            dp_ref, top_s_out, top_i_out, computed_ref, elem_ref = rest[:5]
            top_s, top_i = rest[5:]
        else:
            top_s_out, top_i_out, computed_ref = rest[:3]
            top_s, top_i = rest[3:]
        i = pl.program_id(0)
        j = pl.program_id(1)
        nj = pl.num_programs(1)
        # best-first: step j of query tile i visits db tile order[i, j]
        # (the BlockSpec index maps fetched that tile; this is the global
        # column base for id bookkeeping)
        jb = order_ref[i, j]

        @pl.when(j == 0)
        def _init():
            # warm-start: seed every slot with tau[q] (a true lower bound on
            # the query's k-th best similarity, from a cheap pre-scan of its
            # best-bound block) so early tiles already prune; -inf when
            # disabled.  The seed sits a hair below the real value so that
            # genuine candidates with sim == tau strictly displace seeds —
            # exactness is preserved because >= k real candidates reach tau.
            top_s[...] = jnp.broadcast_to(tau_ref[...], top_s.shape)
            top_i[...] = jnp.full(top_i.shape, -1, jnp.int32)

        qp = qp_ref[...].astype(jnp.float32)              # [BM, P]
        lo = lo_ref[...].astype(jnp.float32)              # [1, P]
        hi = hi_ref[...].astype(jnp.float32)
        rad_q = jnp.maximum(0.0, 1.0 - qp * qp)
        ub_l = qp * lo + jnp.sqrt(rad_q * jnp.maximum(0.0, 1.0 - lo * lo))
        ub_h = qp * hi + jnp.sqrt(rad_q * jnp.maximum(0.0, 1.0 - hi * hi))
        per_p = jnp.where((qp >= lo) & (qp <= hi), 1.0, jnp.maximum(ub_l, ub_h))
        # empty-block sentinel (lo=+inf > hi=-inf, all rows invalid): the
        # raw formula yields NaN (qp=0) or +inf here.  Both are safe —
        # NaN >= tau is False so the tile skips; +inf computes the tile and
        # vmask masks every row.  No explicit branch needed in-kernel.
        ub = per_p.min(axis=-1)                           # [BM]
        if use_cap:
            # extra pivot-similarity operand: the precomputed joint
            # multi-pivot cap for this (query row, visited tile) — min of
            # valid upper bounds is a valid upper bound (DESIGN.md §3.8)
            ub = jnp.minimum(ub, cap_ref[...][:, 0])

        tau = top_s[:, k - 1]                             # running kth best
        row = i * bm + jax.lax.broadcasted_iota(jnp.int32, (qp.shape[0], 1), 0)[:, 0]
        live = row < nvalid_ref[0, 1]                     # padded query rows
        # per-row db validity for this tile: padding AND tombstoned rows.
        # Mutable indexes (repro.core.online) tombstone-delete in place, so
        # valid rows need not be a prefix — a scalar n_valid cut-off would
        # score deleted rows into the top-k.
        vmask = rv_ref[...][:, 0] > 0                     # [BN]
        if prune:
            # padded query rows (>= m_valid) must not force computation
            needed = jnp.any((ub + margin >= tau) & live)
        else:
            needed = True

        if element_stats:
            # per-(query, row) Eq. 13 bound vs the running τ at visit time —
            # the same statistic the scan backend accumulates, so
            # elem_prune_frac is backend-uniform.  Counted regardless of
            # whether the tile matmul itself was skipped (the statistic
            # measures bound power, not work done); unrolled over the P
            # pivots to keep intermediates at [BM, BN].
            dpv = dp_ref[...].astype(jnp.float32)         # [BN, P]
            eub = None
            for p_i in range(dpv.shape[1]):
                a = qp[:, p_i:p_i + 1]                    # [BM, 1]
                b = dpv[:, p_i][None, :]                  # [1, BN]
                rad = rad_q[:, p_i:p_i + 1] * jnp.maximum(0.0, 1.0 - b * b)
                cand = a * b + jnp.sqrt(rad)
                eub = cand if eub is None else jnp.minimum(eub, cand)
            epruned = ((eub + margin < tau[:, None])
                       & vmask[None, :] & live[:, None])
            elem_ref[0, 0] = epruned.sum().astype(jnp.int32)

        @pl.when(needed)
        def _compute():
            qn = qn_ref[...]
            db = db_ref[...]
            scores = jax.lax.dot_general(
                qn, db, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )                                             # [BM, BN]
            col = jb * bn + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
            scores = jnp.where(vmask[None, :], scores, _NEG_INF)  # pad/tombstone
            cand_s = jnp.concatenate([top_s[...], scores], axis=1)
            cand_i = jnp.concatenate([top_i[...], col], axis=1)
            width = cand_s.shape[1]
            lanes = jax.lax.broadcasted_iota(jnp.int32, (cand_s.shape[0], width), 1)
            new_s = []
            new_i = []
            for _ in range(k):                            # unrolled extraction
                m = jnp.max(cand_s, axis=1)
                am = jnp.argmax(cand_s, axis=1).astype(jnp.int32)
                onehot = lanes == am[:, None]
                new_s.append(m)
                new_i.append(jnp.sum(jnp.where(onehot, cand_i, 0), axis=1))
                cand_s = jnp.where(onehot, _NEG_INF, cand_s)
            top_s[...] = jnp.stack(new_s, axis=1)
            top_i[...] = jnp.stack(new_i, axis=1)

        computed_ref[0, 0] = needed.astype(jnp.int32) if prune else jnp.int32(1)

        @pl.when(j == nj - 1)
        def _flush():
            top_s_out[...] = top_s[...]
            top_i_out[...] = top_i[...]

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("k", "bm", "bn", "margin", "prune", "interpret",
                     "element_stats"),
)
def pruned_topk(
    qn: Array,
    db: Array,
    qp: Array,
    dp_min: Array,
    dp_max: Array,
    n_valid: Array | int,
    m_valid: Array | int | None = None,
    tau_init: Array | None = None,
    block_order: Array | None = None,
    dp: Array | None = None,
    ub_cap: Array | None = None,
    row_valid: Array | None = None,
    *,
    k: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    margin: float = 4e-7,
    prune: bool = True,
    interpret: bool = False,
    element_stats: bool = False,
):
    """Fused exact top-k with block pruning.

    Args:
      qn:      [M, D] L2-normalized queries.
      db:      [N, D] L2-normalized database (padding rows at the END).
      qp:      [M, P] query-pivot similarities.
      dp_min/dp_max: [N // bn, P] pivot intervals at KERNEL tile granularity
               (use :func:`repro.search.backends.coarsen_intervals`).
      n_valid: number of real rows in db.
      tau_init: [M] optional τ warm-start seeds (true lower bounds on each
               query's k-th best; see SearchEngine and DESIGN.md §3.4 for
               the multi-block prescan that produces them).
      block_order: [M_tiles, N_tiles] i32 optional per-query-tile db tile
               visiting order (best-first).  Scalar-prefetched: the
               BlockSpec index maps read it, so a pruned tile's HBM->VMEM
               copy targets the *bound-ordered* tile, and sequential steps
               see monotonically less useful tiles — τ rises early.
               Identity order when None.
      dp:      [N, P] per-row pivot similarities; required when
               ``element_stats`` (the per-element Eq. 13 bound needs them).
      ub_cap:  [M, N_tiles] optional extra per-(query, db tile) upper
               bounds (the joint multi-pivot cap, DESIGN.md §3.8),
               min'd into the interval bound inside the kernel before the
               skip test.  Must be valid upper bounds on every score in
               the tile; exactness is the caller's obligation.
      row_valid: [N] optional bool/int per-row validity.  ``None`` (the
               frozen-index case) derives the classic prefix mask
               ``arange(N) < n_valid``.  Pass the index's ``valid`` vector
               when rows can be tombstoned in place (mutable indexes,
               DESIGN.md §3.9): the kernel masks scores per ROW, so
               validity need not be a prefix.
      k:       top-k (k <= bn).
      element_stats: also count, per visited tile, the (query, row) pairs
               whose individual Eq. 13 bound is below the running τ — the
               backend-uniform ``elem_prune_frac`` numerator.

    Returns (sims [M, k] f32, idx [M, k] i32 positions into db,
    computed [M_tiles, N_tiles] i32 — which db tiles did real work, indexed
    by TILE id, not visit step — and elem_pruned [M_tiles, N_tiles] i32
    per-tile pruned-element counts, ``None`` unless ``element_stats``).
    """
    m, d = qn.shape
    n = db.shape[0]
    p = qp.shape[1]
    assert n % bn == 0 and dp_min.shape[0] == n // bn, (n, bn, dp_min.shape)
    assert k <= bn, "k must fit in one db tile"
    if element_stats and dp is None:
        raise ValueError("element_stats=True requires dp ([N, P] per-row "
                         "pivot similarities)")
    mp = -(-m // bm) * bm
    qn_p = jnp.pad(qn, ((0, mp - m), (0, 0)))
    # padded query rows are masked out of the prune predicate via m_valid
    qp_p = jnp.pad(qp, ((0, mp - m), (0, 0)), constant_values=1.0)
    if m_valid is None:
        m_valid = m
    nv = jnp.stack([
        jnp.asarray(n_valid, jnp.int32).reshape(()),
        jnp.asarray(m_valid, jnp.int32).reshape(()),
    ]).reshape(1, 2)
    if row_valid is None:
        row_valid = jnp.arange(n) < jnp.asarray(n_valid, jnp.int32)
    rv = row_valid.astype(jnp.int32).reshape(n, 1)
    if tau_init is None:
        tau = jnp.full((mp, 1), _NEG_INF, jnp.float32)
    else:
        tau = jnp.pad(tau_init.reshape(m, 1).astype(jnp.float32) - 1e-6,
                      ((0, mp - m), (0, 0)), constant_values=_NEG_INF)
    grid = (mp // bm, n // bn)
    if block_order is None:
        block_order = jnp.broadcast_to(
            jnp.arange(grid[1], dtype=jnp.int32)[None, :], grid)
    block_order = block_order.astype(jnp.int32)
    assert block_order.shape == grid, (block_order.shape, grid)
    use_cap = ub_cap is not None
    kern = _make_kernel(k, bm, bn, margin, prune, element_stats,
                        use_cap=use_cap)
    out_shape = [
        jax.ShapeDtypeStruct((mp, k), jnp.float32),
        jax.ShapeDtypeStruct((mp, k), jnp.int32),
        jax.ShapeDtypeStruct(grid, jnp.int32),
    ]
    in_specs = [
        pl.BlockSpec((1, 2), lambda i, j, ord_: (0, 0)),  # n_valid, m_valid
        pl.BlockSpec((bm, 1), lambda i, j, ord_: (i, 0)),  # tau seeds
        pl.BlockSpec((bm, d), lambda i, j, ord_: (i, 0)),  # qn
        pl.BlockSpec((bn, d), lambda i, j, ord_: (ord_[i, j], 0)),  # db
        pl.BlockSpec((bm, p), lambda i, j, ord_: (i, 0)),  # qp
        pl.BlockSpec((1, p), lambda i, j, ord_: (ord_[i, j], 0)),   # lo
        pl.BlockSpec((1, p), lambda i, j, ord_: (ord_[i, j], 0)),   # hi
        pl.BlockSpec((bn, 1), lambda i, j, ord_: (ord_[i, j], 0)),  # row valid
    ]
    out_specs = [
        pl.BlockSpec((bm, k), lambda i, j, ord_: (i, 0)),
        pl.BlockSpec((bm, k), lambda i, j, ord_: (i, 0)),
        # computed is indexed by the VISITED tile id, not the step
        pl.BlockSpec((1, 1), lambda i, j, ord_: (i, ord_[i, j])),
    ]
    operands = [block_order, nv, tau, qn_p, db, qp_p, dp_min, dp_max, rv]
    if use_cap:
        assert ub_cap.shape == (m, grid[1]), (ub_cap.shape, m, grid)
        # padded query rows carry cap 0: their ub shrinks, but the prune
        # predicate already masks them out via m_valid / `live`
        cap_p = jnp.pad(ub_cap.astype(jnp.float32), ((0, mp - m), (0, 0)))
        in_specs.append(
            pl.BlockSpec((bm, 1), lambda i, j, ord_: (i, ord_[i, j])))
        operands.append(cap_p)
    if element_stats:
        in_specs.append(
            pl.BlockSpec((bn, p), lambda i, j, ord_: (ord_[i, j], 0)))  # dp
        operands.append(dp)
        out_shape.append(jax.ShapeDtypeStruct(grid, jnp.int32))
        out_specs.append(
            pl.BlockSpec((1, 1), lambda i, j, ord_: (i, ord_[i, j])))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                                # block_order
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((bm, k), jnp.float32),
            pltpu.VMEM((bm, k), jnp.int32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    top_s, top_i, computed = out[:3]
    elem = out[3] if element_stats else None
    return top_s[:m], top_i[:m], computed, elem
