"""Pure-jnp oracles for the Pallas kernels (no Pallas imports).

Each kernel in this package asserts allclose against these in
``tests/test_kernels.py`` across a sweep of shapes and dtypes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array


def l2_normalize(x: Array, eps: float = 1e-12) -> Array:
    n = jnp.sqrt(jnp.sum(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True))
    return (x.astype(jnp.float32) / jnp.maximum(n, eps)).astype(x.dtype)


def cosine_scores(q: Array, db: Array) -> Array:
    """All-pairs cosine similarity with fused normalization; f32 accumulate."""
    qn = l2_normalize(q).astype(jnp.float32)
    dbn = l2_normalize(db).astype(jnp.float32)
    return qn @ dbn.T


def block_bounds(qp: Array, dp_min: Array, dp_max: Array) -> Array:
    """Per-(query, block) Eq. 13 interval upper bound, min over pivots.

    qp: [M, P]; dp_min/dp_max: [NB, P] -> [M, NB] f32.
    """
    qp = qp.astype(jnp.float32)[:, None, :]       # [M, 1, P]
    lo = dp_min.astype(jnp.float32)[None, :, :]   # [1, NB, P]
    hi = dp_max.astype(jnp.float32)[None, :, :]
    rad_q = jnp.maximum(0.0, 1.0 - qp * qp)
    ub_lo = qp * lo + jnp.sqrt(rad_q * jnp.maximum(0.0, 1.0 - lo * lo))
    ub_hi = qp * hi + jnp.sqrt(rad_q * jnp.maximum(0.0, 1.0 - hi * hi))
    at_ends = jnp.maximum(ub_lo, ub_hi)
    inside = (qp >= lo) & (qp <= hi)
    per_pivot = jnp.where(inside, 1.0, at_ends)
    # lo > hi is the empty-block sentinel (+inf/-inf for all-padding
    # blocks): no reachable similarity, so the bound is -inf and the block
    # prunes unconditionally instead of leaking NaN/+inf from the raw
    # formula above.
    per_pivot = jnp.where(lo > hi, -jnp.inf, per_pivot)
    return per_pivot.min(axis=-1)                 # [M, NB]


def kth_value(scores: Array, k: int) -> Array:
    """Row-wise k-th highest value, guarded to keep the fast TopK lowering.

    ``lax.top_k(x, k)[0][:, -1]`` looks innocent, but jax lowers ``top_k``
    as sort+slice and XLA's TopkRewriter only recognizes slices starting
    at column 0: composing ``[:, -1]`` folds into a ``[k-1:k]`` slice, the
    pattern dies, and the whole thing silently runs as a full O(n log n)
    sort — ~10x slower on CPU at [64, 128] (measured 812µs vs 80µs).  The
    ``optimization_barrier`` pins the intact [m, k] values so the rewrite
    fires; the k-th column is sliced outside the barrier.  The compat
    wrapper (local import: kernels must not import dist at module scope)
    keeps the barrier differentiable on this jax.
    """
    from repro.dist.compat import optimization_barrier

    vals = optimization_barrier(jax.lax.top_k(scores, k)[0])
    return vals[:, -1]


def cosine_topk(q: Array, db: Array, k: int, valid: Array | None = None):
    """Exact top-k cosine (sims f32, idx i32).  ``valid`` masks db rows."""
    s = cosine_scores(q, db)
    if valid is not None:
        s = jnp.where(valid[None, :], s, -jnp.inf)
    sims, idx = jax.lax.top_k(s, k)
    return sims, idx.astype(jnp.int32)


def pruned_cosine_topk(
    q: Array,
    db: Array,
    qp: Array,
    dp_min: Array,
    dp_max: Array,
    k: int,
    valid: Array | None = None,
    margin: float = 4e-7,
):
    """Oracle for the fused kernel *including* its pruning bookkeeping.

    Returns (sims, idx, blocks_computed [M_tiles? -> scalar fraction proxy]).
    The result must equal plain :func:`cosine_topk` — pruning never changes
    the answer; only the computed-block count differs.
    """
    sims, idx = cosine_topk(q, db, k, valid)
    ub = block_bounds(qp, dp_min, dp_max)         # [M, NB]
    # kth best per query after full search (the final tau)
    tau = sims[:, -1]
    # a block could have been pruned if its ub (plus margin) is below the
    # final tau for EVERY query in the tile — tile-size dependent, so here we
    # report the per-(query, block) prunable fraction as an upper estimate.
    prunable = (ub + margin) < tau[:, None]
    return sims, idx, prunable.mean()
