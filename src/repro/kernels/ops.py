"""Deprecated jit'd wrappers around the Pallas kernels.

The kernel search path moved into the unified runtime:
:class:`repro.search.SearchEngine` with ``backend="kernel"`` (or the raw
inner loop :func:`repro.search.backends.kernel_search`).  This module keeps
the old entry points alive for existing callers; new code should go through
the engine, which adds τ warm-start and best-first block ordering on top.
"""
from __future__ import annotations

import warnings

import jax
from jax import Array

from repro.core.index import BlockIndex
from repro.kernels import bound_prune, cosine_topk  # noqa: F401  (re-export)
from repro.search.backends import coarsen_intervals  # noqa: F401  (moved)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def block_bounds(qp: Array, dp_min: Array, dp_max: Array, *, interpret=None) -> Array:
    """Kernel-backed Eq. 13 block bounds ([M,P] x [NB,P] -> [M,NB])."""
    if interpret is None:
        interpret = _on_cpu()
    return bound_prune.block_bounds(qp, dp_min, dp_max, interpret=interpret)


def search_index(
    index: BlockIndex,
    queries: Array,
    k: int,
    *,
    bm: int = cosine_topk.DEFAULT_BM,
    bn: int | None = None,
    prune: bool = True,
    sort_queries: bool = True,
    warm_start: bool = False,
    best_first: bool = False,
    interpret: bool | None = None,
):
    """Deprecated: use ``SearchEngine(index, backend="kernel")``.

    Returns (sims [m,k], original row ids [m,k], computed_tile_frac scalar)
    exactly as before; defaults preserve the historical behavior
    (warm-start and best-first off).
    """
    warnings.warn(
        "repro.kernels.ops.search_index is deprecated; use "
        "repro.search.SearchEngine(index, backend='kernel')",
        DeprecationWarning, stacklevel=2)
    from repro.search.backends import (kernel_search, map_row_ids,
                                       prep_queries)
    qn, qp = prep_queries(index, queries)
    sims, pos, computed, _ = kernel_search(
        index, qn, qp, k, bm=bm, bn=bn, prune=prune,
        sort_queries=sort_queries, warm_start=warm_start,
        best_first=best_first, interpret=interpret)
    ids = map_row_ids(index.row_ids, pos)
    return sims, ids, computed.mean()
