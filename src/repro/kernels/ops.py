"""Legacy jit'd wrappers around the Pallas kernels.

The kernel search path moved into the unified runtime:
:class:`repro.search.SearchEngine` with ``backend="kernel"`` (or the raw
inner loop :func:`repro.search.backends.kernel_search`).  The old
``search_index`` entry point spent one release as a DeprecationWarning
shim and is now a hard error (see docs/search-api.md for the migration
table); ``block_bounds`` remains a supported thin wrapper.
"""
from __future__ import annotations

import jax
from jax import Array

from repro.kernels import bound_prune, cosine_topk  # noqa: F401  (re-export)
from repro.search.backends import coarsen_intervals  # noqa: F401  (moved)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def block_bounds(qp: Array, dp_min: Array, dp_max: Array, *, interpret=None) -> Array:
    """Kernel-backed Eq. 13 block bounds ([M,P] x [NB,P] -> [M,NB])."""
    if interpret is None:
        interpret = _on_cpu()
    return bound_prune.block_bounds(qp, dp_min, dp_max, interpret=interpret)


def search_index(*args, **kwargs):
    """Removed: use ``SearchEngine(index, backend="kernel")``.

    The shim's historical defaults (warm-start and best-first off) made
    its numbers incomparable with the engine's kernel backend, so it no
    longer executes.  For the raw fixed-policy inner loop, call
    :func:`repro.search.backends.kernel_search` directly.
    """
    raise TypeError(
        "repro.kernels.ops.search_index() was removed. Use "
        "repro.search.SearchEngine(index, backend='kernel').search(queries, "
        "k), or the raw inner loop repro.search.backends.kernel_search. "
        "The migration table is in docs/search-api.md.")
