"""Jit'd public wrappers around the Pallas kernels.

``searcher`` bridges a :class:`repro.core.index.BlockIndex` to the fused
kernel: it coarsens the index's per-block pivot intervals to kernel-tile
granularity, normalizes the queries, and maps results back to original row
ids.  On CPU (this container) the kernels run with ``interpret=True``; on
TPU the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.index import BlockIndex
from repro.core.pivots import normalize
from repro.kernels import bound_prune, cosine_topk


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def coarsen_intervals(dp_min: Array, dp_max: Array, factor: int):
    """Merge ``factor`` consecutive index blocks into one kernel tile."""
    nb, p = dp_min.shape
    assert nb % factor == 0, (nb, factor)
    lo = dp_min.reshape(nb // factor, factor, p).min(axis=1)
    hi = dp_max.reshape(nb // factor, factor, p).max(axis=1)
    return lo, hi


def block_bounds(qp: Array, dp_min: Array, dp_max: Array, *, interpret=None) -> Array:
    """Kernel-backed Eq. 13 block bounds ([M,P] x [NB,P] -> [M,NB])."""
    if interpret is None:
        interpret = _on_cpu()
    return bound_prune.block_bounds(qp, dp_min, dp_max, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("k", "bm", "bn", "prune", "sort_queries",
                              "warm_start", "interpret")
)
def search_index(
    index: BlockIndex,
    queries: Array,
    k: int,
    *,
    bm: int = cosine_topk.DEFAULT_BM,
    bn: int | None = None,
    prune: bool = True,
    sort_queries: bool = True,
    warm_start: bool = False,
    interpret: bool | None = None,
):
    """Kernel-backed exact top-k over a BlockIndex.

    Returns (sims [m,k], original row ids [m,k], computed_tile_frac scalar).
    Functionally identical to :func:`repro.core.index.search` (tested), but
    the pruned tiles genuinely skip their matmul.

    ``sort_queries`` (beyond-paper): the kernel prunes a db tile only when
    *no* query in the BM-row query tile needs it, so mixed batches defeat
    pruning.  Grouping queries by their nearest pivot makes query tiles
    angularly coherent; results are unsorted back before returning.
    """
    if interpret is None:
        interpret = _on_cpu()
    n_pad = index.db.shape[0]
    ibs = index.block_size
    if bn is None:
        bn = ibs if ibs % 128 == 0 else ibs * max(1, -(-128 // ibs))
    # kernel tile must be a multiple of the index block size dividing n_pad
    while n_pad % bn or bn % ibs:
        bn //= 2
        if bn < ibs:
            bn = ibs
            break
    factor = bn // ibs
    lo, hi = coarsen_intervals(index.dp_min, index.dp_max, factor)
    qn = normalize(jnp.asarray(queries, jnp.float32))
    qp = qn @ index.pivots.T
    if sort_queries:
        key = jnp.argmax(qp, axis=1).astype(jnp.float32) * 4.0 - jnp.max(qp, axis=1)
        perm = jnp.argsort(key)
        qn, qp = qn[perm], qp[perm]
    n_valid = index.valid.sum().astype(jnp.int32)
    tau_init = None
    if warm_start:
        # tau warm-start (beyond-paper): pre-scan each query's best-bound
        # block to seed the kernel's k-th-best threshold.  Cost: one
        # [m, bn] x d matmul; exactness unaffected (tau is a true lower
        # bound achieved by k real candidates of that block).
        from repro.kernels import ref as kref
        ub = kref.block_bounds(qp, lo, hi)                   # [m, NB]
        best = jnp.argmax(ub, axis=1)                        # [m]
        blk = index.db.reshape(-1, bn, index.db.shape[-1])[best]   # [m,bn,d]
        vmask = index.valid.reshape(-1, bn)[best]            # [m, bn]
        scores = jnp.einsum("md,mbd->mb", qn, blk)
        scores = jnp.where(vmask, scores, -jnp.inf)
        kk = min(k, bn)
        tau_init = jax.lax.top_k(scores, kk)[0][:, -1]
        tau_init = jnp.where(jnp.isfinite(tau_init), tau_init, -jnp.inf)
    sims, pos, computed = cosine_topk.pruned_topk(
        qn, index.db, qp, lo, hi, n_valid, tau_init=tau_init,
        k=k, bm=bm, bn=bn, prune=prune, interpret=interpret,
    )
    if sort_queries:
        inv = jnp.argsort(perm)
        sims, pos = sims[inv], pos[inv]
    ids = jnp.where(pos >= 0, index.row_ids[jnp.maximum(pos, 0)], -1)
    return sims, ids, computed.mean()
