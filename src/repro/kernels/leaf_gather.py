"""Leaf-gather entry point: run the fused Pallas kernel over a block subset.

The tree backend's descent (:mod:`repro.search.tree`, DESIGN.md §3.5)
proves most blocks irrelevant *before* any kernel is dispatched.  This
module is the bridge from that data-dependent survivor set to the
fixed-shape Pallas kernel: gather the surviving blocks into a contiguous
compact database (one static-shape gather — the TPU analogue of a
pointer-chased leaf visit) and hand it to
:func:`repro.kernels.cosine_topk.pruned_topk` with the kernel tile pinned
to the index block size, so per-block pivot intervals are reused directly
(no coarsening) and the kernel grid shrinks from ``n_blocks`` to
``n_keep`` tiles.

Shape contract: ``keep`` must be sorted ascending (stable tile order for
the best-first permutation and the position mapping).  The compacted
per-row ``valid`` vector rides along as ``pruned_topk``'s ``row_valid``
operand, so validity need not be a prefix — tombstoned rows of a mutable
index (:mod:`repro.core.online`) are masked per row exactly like padding.
Exactness: the caller guarantees the kept set contains every block any
query in the batch still needs; the kernel's own per-tile bound check
then skips kept tiles that a risen τ has since invalidated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.index import BlockIndex
from repro.kernels import cosine_topk
from repro.kernels import ref as kref

__all__ = ["gathered_topk"]


@functools.partial(
    jax.jit,
    static_argnames=("n_keep", "k", "bm", "margin", "interpret",
                     "element_stats", "best_first"),
)
def gathered_topk(
    index: BlockIndex,
    keep: Array,
    qn: Array,
    qp: Array,
    tau0: Array | None,
    *,
    n_keep: int,
    k: int,
    bm: int = cosine_topk.DEFAULT_BM,
    margin: float = 4e-7,
    interpret: bool = False,
    element_stats: bool = False,
    best_first: bool = True,
):
    """Fused pruned top-k over the ``keep`` subset of index blocks.

    Args:
      index: the (single-shard) :class:`BlockIndex`.
      keep: [n_keep] i32 block ids, sorted ascending (see module doc).
      qn / qp: normalized queries and their pivot similarities.
      tau0: [m] τ warm-start seeds or ``None``.
      n_keep: static length of ``keep`` (host-known survivor count).
      k: top-k; must satisfy ``k <= block_size`` (kernel tile constraint).
      best_first: per-query-tile bound-descending visit order over the
        kept tiles (scalar-prefetched, as in the flat kernel backend).

    Returns ``(sims [m, k], pos [m, k] positions into the ORIGINAL padded
    db, computed [m_tiles, n_keep] i32, elem [m_tiles, n_keep] i32 or
    None)`` — positions are mapped back through ``keep`` so callers can
    use the usual ``map_row_ids``.
    """
    nb, bs = index.n_blocks, index.block_size
    d = index.db.shape[1]
    m = qn.shape[0]
    assert k <= bs, "kernel leaf stage needs k <= block_size"

    db_c = index.db.reshape(nb, bs, d)[keep].reshape(n_keep * bs, d)
    valid_c = index.valid.reshape(nb, bs)[keep].reshape(n_keep * bs)
    lo_c = index.dp_min[keep]                                  # [n_keep, P]
    hi_c = index.dp_max[keep]
    n_valid = valid_c.sum().astype(jnp.int32)

    block_order = None
    if best_first:
        ub = kref.block_bounds(qp, lo_c, hi_c)                 # [m, n_keep]
        mp = -(-m // bm) * bm
        ub_p = jnp.pad(ub, ((0, mp - m), (0, 0)), constant_values=-jnp.inf)
        tile_ub = ub_p.reshape(mp // bm, bm, n_keep).max(axis=1)
        block_order = jnp.argsort(-tile_ub, axis=1).astype(jnp.int32)

    dp_c = None
    if element_stats:
        dp_c = index.dp.reshape(nb, bs, -1)[keep].reshape(n_keep * bs, -1)

    sims, pos, computed, elem = cosine_topk.pruned_topk(
        qn, db_c, qp, lo_c, hi_c, n_valid,
        tau_init=tau0, block_order=block_order, dp=dp_c, row_valid=valid_c,
        k=k, bm=bm, bn=bs, margin=margin, prune=True, interpret=interpret,
        element_stats=element_stats)

    # compact positions -> original padded-db positions (−1 stays −1)
    blk = jnp.clip(pos // bs, 0, n_keep - 1)
    orig = jnp.where(pos >= 0, keep[blk] * bs + pos % bs, -1)
    return sims, orig.astype(jnp.int32), computed, elem
