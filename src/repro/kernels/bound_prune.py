"""Pallas kernel: Eq. 13 block upper bounds over pivot intervals.

Computes ``ub[m, b] = min_p max_{s in [lo[b,p], hi[b,p]]} ub_mult(qp[m,p], s)``
— the pruning predicate of the block index — as a standalone kernel so the
bound evaluation itself runs at VPU rate with VMEM-resident tiles.

Pure elementwise + small reduction: the kernel exists because on TPU the
bound evaluation for millions of (query, block) pairs is the *second*
hot-spot after the score matmul, and fusing the min-over-pivots avoids
materializing the [M, NB, P] intermediate in HBM (P× traffic reduction —
this is the memory-bound term in the roofline).

Grid: (M/BM, NB/BB).  Tiles: qp [BM, P], lo/hi [BB, P], out [BM, BB].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BB = 256


def _kernel(qp_ref, lo_ref, hi_ref, out_ref):
    out_ref[...] = _interval_ub(qp_ref, lo_ref, hi_ref)


def _kernel_cap(qp_ref, lo_ref, hi_ref, cap_ref, out_ref):
    # extra pivot-similarity operand: intersect the precomputed joint
    # multi-pivot cap tile — min of valid upper bounds stays valid
    out_ref[...] = jnp.minimum(_interval_ub(qp_ref, lo_ref, hi_ref),
                               cap_ref[...].astype(jnp.float32))


def _interval_ub(qp_ref, lo_ref, hi_ref):
    qp = qp_ref[...].astype(jnp.float32)          # [BM, P]
    lo = lo_ref[...].astype(jnp.float32)          # [BB, P]
    hi = hi_ref[...].astype(jnp.float32)
    a = qp[:, None, :]                            # [BM, 1, P]
    l = lo[None, :, :]                            # [1, BB, P]
    h = hi[None, :, :]
    rad_a = jnp.maximum(0.0, 1.0 - a * a)
    ub_l = a * l + jnp.sqrt(rad_a * jnp.maximum(0.0, 1.0 - l * l))
    ub_h = a * h + jnp.sqrt(rad_a * jnp.maximum(0.0, 1.0 - h * h))
    per_pivot = jnp.where((a >= l) & (a <= h), 1.0, jnp.maximum(ub_l, ub_h))
    # inverted interval (l > h): the empty-block sentinel — bound is -inf
    # (keeps this kernel value-identical to kref.block_bounds on indexes
    # that carry all-padding blocks from online mutation)
    per_pivot = jnp.where(l > h, -jnp.inf, per_pivot)
    return per_pivot.min(axis=-1)                 # [BM, BB]


@functools.partial(jax.jit, static_argnames=("bm", "bb", "interpret"))
def block_bounds(
    qp: Array,
    dp_min: Array,
    dp_max: Array,
    ub_cap: Array | None = None,
    *,
    bm: int = DEFAULT_BM,
    bb: int = DEFAULT_BB,
    interpret: bool = False,
) -> Array:
    """[M, P] x [NB, P] -> [M, NB] block upper bounds (f32).

    M and NB are padded internally to tile multiples; P stays whole (pivot
    counts are small, 8–64, and live in the minor-most VMEM lane dim).

    ``ub_cap`` [M, NB] (optional) is an extra per-(query, block) upper
    bound — the joint multi-pivot cap of DESIGN.md §3.8 — intersected with
    the interval bound inside the kernel (tightest wins; validity is the
    caller's obligation).
    """
    m, p = qp.shape
    nb = dp_min.shape[0]
    bm_, bb_ = min(bm, max(m, 8)), min(bb, max(nb, 8))
    mp = -(-m // bm_) * bm_
    nbp = -(-nb // bb_) * bb_
    qp_p = jnp.pad(qp, ((0, mp - m), (0, 0)))
    # pad blocks with degenerate interval [2, 2]^c -> inside=False and
    # ub <= ... values unused (sliced off below); any finite pad is fine.
    lo_p = jnp.pad(dp_min, ((0, nbp - nb), (0, 0)), constant_values=0.0)
    hi_p = jnp.pad(dp_max, ((0, nbp - nb), (0, 0)), constant_values=0.0)
    in_specs = [
        pl.BlockSpec((bm_, p), lambda i, j: (i, 0)),
        pl.BlockSpec((bb_, p), lambda i, j: (j, 0)),
        pl.BlockSpec((bb_, p), lambda i, j: (j, 0)),
    ]
    operands = [qp_p, lo_p, hi_p]
    kern = _kernel
    if ub_cap is not None:
        assert ub_cap.shape == (m, nb), (ub_cap.shape, m, nb)
        # padded cells are sliced off below; any finite pad is fine
        cap_p = jnp.pad(ub_cap.astype(jnp.float32),
                        ((0, mp - m), (0, nbp - nb)))
        in_specs.append(pl.BlockSpec((bm_, bb_), lambda i, j: (i, j)))
        operands.append(cap_p)
        kern = _kernel_cap
    out = pl.pallas_call(
        kern,
        grid=(mp // bm_, nbp // bb_),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm_, bb_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, nbp), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:m, :nb]
