"""subpackage."""
