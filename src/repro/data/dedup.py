"""Near-duplicate filtering via the paper's exact search (data layer).

Documents are embedded (any encoder; tests/examples use hashed bag-of-tokens
projections) and pairs with cosine >= 1 - eps are deduplicated.  This is the
regime where Eq. 13 pruning is strongest: duplicate thresholds are close to
1, so nearly every block's upper bound falls below tau and the exact-match
matmuls collapse to a tiny fraction (measured in benchmarks/pruning_power).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.search import SearchEngine


def embed_tokens(tokens: np.ndarray, dim: int = 256, seed: int = 0) -> np.ndarray:
    """Hashed bag-of-tokens embedding [n_docs, dim] (deterministic)."""
    rng = np.random.default_rng(seed)
    vocab_proj = None
    n, s = tokens.shape
    out = np.zeros((n, dim), np.float32)
    # feature-hash each token id into dim buckets with +-1 signs
    h = (tokens.astype(np.int64) * 2654435761) % dim
    sign = np.where(((tokens.astype(np.int64) * 40503) % 2) == 0, 1.0, -1.0)
    for i in range(n):
        np.add.at(out[i], h[i], sign[i])
    return out


def find_near_duplicates(embeddings: np.ndarray, *, threshold: float = 0.95,
                         k: int = 8, n_pivots: int = 16,
                         block_size: int = 128):
    """Return (pairs [(i, j), ...] with i<j and sim>=threshold, stats)."""
    emb = jnp.asarray(embeddings, jnp.float32)
    eng = SearchEngine.build(emb, n_pivots=n_pivots, block_size=block_size)
    sims, ids, stats = eng.search(emb, k + 1)    # +1: self-match
    sims, ids = np.asarray(sims), np.asarray(ids)
    pairs = set()
    for i in range(len(emb)):
        for s, j in zip(sims[i], ids[i]):
            if j < 0 or j == i or s < threshold:
                continue
            pairs.add((min(i, int(j)), max(i, int(j))))
    return sorted(pairs), stats


def dedup_mask(n: int, pairs) -> np.ndarray:
    """Keep-mask: for each duplicate pair drop the larger index."""
    keep = np.ones((n,), bool)
    for i, j in pairs:
        if keep[i] and keep[j]:
            keep[j] = False
    return keep
