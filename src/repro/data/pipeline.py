"""Deterministic, resumable, host-sharded data pipeline.

Two sources behind one interface:

* ``SyntheticLM`` — a stateless PRNG stream: batch(step) is a pure function
  of (seed, step, shard), so resume-after-preemption is exact with no state
  beyond the step counter, and every host generates only its own shard.
* ``TokenFileSource`` — fixed-width samples from a binary token file via
  ``np.memmap`` with a deterministic epoch shuffle (Feistel-style index
  permutation, O(1) state).

Both return host-local numpy arrays; the trainer assembles them into
globally-sharded ``jax.Array``s with ``jax.make_array_from_process_local_data``
(or plain device_put on a single process).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ShardInfo:
    shard: int = 0
    num_shards: int = 1


class SyntheticLM:
    """Zipf-ish synthetic token stream with planted n-gram structure (so a
    model actually learns and loss decreases — used by examples/tests)."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, shard: ShardInfo = ShardInfo()):
        assert global_batch % shard.num_shards == 0
        self.vocab, self.seq = vocab, seq_len
        self.local_batch = global_batch // shard.num_shards
        self.seed, self.shard = seed, shard

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard.shard]))
        b, s, v = self.local_batch, self.seq, self.vocab
        # order-2 markov-ish: next token = f(prev) + noise; cheap + learnable
        base = rng.zipf(1.5, size=(b, s)).astype(np.int64) % v
        tok = np.empty((b, s), np.int32)
        tok[:, 0] = base[:, 0]
        mult = 31
        for t in range(1, s):
            det = (tok[:, t - 1] * mult + 7) % v
            use_det = rng.random(b) < 0.7
            tok[:, t] = np.where(use_det, det, base[:, t])
        labels = np.roll(tok, -1, axis=1)
        labels[:, -1] = tok[:, 0]
        return {"tokens": tok, "labels": labels}

    def state(self) -> dict:
        return {"kind": "synthetic", "seed": self.seed}

    # stateless: nothing to restore beyond the trainer's step counter
    def restore(self, state: dict) -> None:
        assert state.get("kind") == "synthetic"


def _feistel(idx: np.ndarray, n: int, key: int, rounds: int = 4) -> np.ndarray:
    """Deterministic permutation of [0, n) (cycle-walking Feistel)."""
    bits = max(2, int(np.ceil(np.log2(max(n, 2)))))
    half = bits // 2
    mask = (1 << half) - 1
    out = idx.astype(np.uint64)

    def perm(x):
        l, r = x >> half, x & mask
        for rnd in range(rounds):
            f = ((r * np.uint64(0x9E3779B1) + np.uint64(key + rnd)) >>
                 np.uint64(15)) & mask
            l, r = r, l ^ f
        return (l << half) | r

    out = perm(out)
    for _ in range(4):  # cycle-walk back into range
        oob = out >= n
        if not oob.any():
            break
        out = np.where(oob, perm(out), out)
    return np.where(out >= n, idx, out).astype(np.int64)


class TokenFileSource:
    """Fixed-width samples from a flat binary int32 token file."""

    def __init__(self, path: str, seq_len: int, global_batch: int,
                 seed: int = 0, shard: ShardInfo = ShardInfo()):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.seq = seq_len
        assert global_batch % shard.num_shards == 0
        self.local_batch = global_batch // shard.num_shards
        self.n_samples = len(self.data) // (seq_len + 1)
        self.seed, self.shard = seed, shard
        assert self.n_samples >= global_batch, "file too small"

    def batch(self, step: int) -> dict:
        gb = self.local_batch * self.shard.num_shards
        epoch = (step * gb) // self.n_samples
        offs = (step * gb) % self.n_samples
        idx = (offs + np.arange(gb)) % self.n_samples
        idx = _feistel(idx, self.n_samples, self.seed + epoch)
        lo = self.shard.shard * self.local_batch
        idx = idx[lo : lo + self.local_batch]
        w = self.seq + 1
        rows = np.stack([self.data[i * w : (i + 1) * w] for i in idx])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}

    def state(self) -> dict:
        return {"kind": "file", "seed": self.seed}

    def restore(self, state: dict) -> None:
        assert state.get("kind") == "file"
