"""subpackage."""
