"""Sharded, async, integrity-checked checkpointing (no orbax dependency).

Layout (one directory per step)::

    <dir>/step_000100/
        manifest.json          tree structure, shapes, dtypes, sha256 per leaf
        shard_p0.npz           this process's leaf arrays (addressable shards)
        DONE                   commit marker (written last -> atomic)

Features needed at fleet scale:
  * async save — a background thread serializes device arrays that were
    first fetched to host at save() call time (so training continues),
  * integrity — per-leaf sha256 in the manifest, verified on restore,
  * elasticity — restore() re-shards onto whatever mesh/sharding the caller
    provides (the array data is mesh-agnostic; `elastic.py` handles picking
    a new mesh after node loss),
  * GC — keep the newest ``keep`` checkpoints,
  * crash safety — a step directory without DONE is ignored and reclaimed.

Multi-host note: each process writes ``shard_p{i}.npz`` with its addressable
shard of every leaf (fully-addressable arrays are written by process 0
only).  This container is single-process; the multi-host write path is the
same code with ``process_index() > 0``.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, extra: dict | None = None,
             block: bool = False):
        """Snapshot ``tree`` (any pytree of arrays) at ``step``."""
        self.wait()                       # one in-flight save at a time
        flat, _ = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}   # fetch NOW
        meta = {
            "step": step,
            "time": time.time(),
            "extra": extra or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
        }

        def write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_p0.npz"), **host)
            for k, v in host.items():
                meta["leaves"][k]["sha256"] = hashlib.sha256(
                    np.ascontiguousarray(v).tobytes()).hexdigest()
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            with open(os.path.join(tmp, "DONE"), "w") as f:
                f.write("ok")
            shutil.rmtree(path, ignore_errors=True)
            os.replace(tmp, path)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "DONE")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, target_tree, step: int | None = None, *,
                shardings=None, verify: bool = True):
        """Restore into the structure of ``target_tree``.

        ``shardings``: optional pytree of NamedSharding (same structure) — the
        elastic-reshard path; arrays are device_put onto them.
        Returns (tree, extra, step).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "shard_p0.npz"))
        flat_t, treedef = _flatten(target_tree)
        flat_s = _flatten(shardings)[0] if shardings is not None else {}
        out = {}
        for key, ref in flat_t.items():
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if verify:
                h = hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()
                if h != meta["leaves"][key]["sha256"]:
                    raise IOError(f"integrity failure on leaf {key!r}")
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"shape mismatch {key}: {arr.shape} vs {ref.shape}")
            arr = arr.astype(ref.dtype)
            if key in flat_s and flat_s[key] is not None:
                out[key] = jax.device_put(arr, flat_s[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        leaves = [out[k] for k in flat_t.keys()]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, meta.get("extra", {}), step

    # -------------------------------------------------------------------- gc
    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
        # reclaim dead tmp dirs
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)
