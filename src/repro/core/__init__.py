"""Core: the paper's triangle inequality for cosine similarity + exact search.

Public surface:
  bounds   — Eq. 7–13 elementwise bound functions (jnp)
  ref      — float64 numpy oracles (independent reference)
  pivots   — pivot selection
  index    — TPU-native block-pruned exact kNN (BlockIndex / build / search)
  vptree   — paper-faithful CPU VP-tree baseline
  distributed — mesh-sharded datastore search
"""
from repro.core import bounds, ref  # noqa: F401
from repro.core.index import BlockIndex, build_index, search, search_brute  # noqa: F401
from repro.core.pivots import normalize, select_pivots_maxmin  # noqa: F401
from repro.core.vptree import VPTree  # noqa: F401
