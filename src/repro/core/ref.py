"""Pure-numpy float64 oracles for the bounds and for exact kNN search.

Everything in :mod:`repro.core` and :mod:`repro.kernels` is validated against
this module.  No JAX imports here on purpose — this is the independent
reference implementation.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "lb_euclid",
    "lb_euclid_fast",
    "lb_arccos",
    "lb_mult",
    "lb_mult_fast1",
    "lb_mult_fast2",
    "ub_mult",
    "cosine_matrix",
    "normalize",
    "brute_force_knn",
    "pruned_knn_reference",
    "LOWER_BOUNDS",
]


def _rad(s):
    return np.maximum(0.0, 1.0 - s * s)


def lb_euclid(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return a + b - 1.0 - 2.0 * np.sqrt(np.maximum(0.0, (1.0 - a) * (1.0 - b)))


def lb_euclid_fast(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return a + b + 2.0 * np.minimum(a, b) - 3.0


def lb_arccos(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return np.cos(np.arccos(np.clip(a, -1, 1)) + np.arccos(np.clip(b, -1, 1)))


def lb_mult(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return a * b - np.sqrt(_rad(a) * _rad(b))


def lb_mult_fast1(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return a * b + np.minimum(a, b) ** 2 - 1.0


def lb_mult_fast2(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return 2.0 * a * b - np.abs(a - b) - 1.0


def ub_mult(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return a * b + np.sqrt(_rad(a) * _rad(b))


def ub_euclid(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return a + b - 1.0 + 2.0 * np.sqrt(np.maximum(0.0, (1.0 - a) * (1.0 - b)))


LOWER_BOUNDS = {
    "euclidean": lb_euclid,
    "eucl_lb": lb_euclid_fast,
    "arccos": lb_arccos,
    "mult": lb_mult,
    "mult_lb1": lb_mult_fast1,
    "mult_lb2": lb_mult_fast2,
}


# ---------------------------------------------------------------------------
# Exact-search oracles
# ---------------------------------------------------------------------------

def normalize(x, eps: float = 1e-12):
    x = np.asarray(x, np.float64)
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), eps)


def cosine_matrix(q, db):
    """All-pairs cosine similarity, float64.  q: [m, d], db: [n, d]."""
    return normalize(q) @ normalize(db).T


def brute_force_knn(q, db, k: int):
    """Exact top-k by cosine similarity.  Returns (sims [m,k], idx [m,k]).

    Ties are broken by ascending index (stable), matching the device kernels.
    """
    s = cosine_matrix(q, db)
    # stable argsort on (-sim, idx): lexsort over keys.
    m, n = s.shape
    order = np.argsort(-s, axis=1, kind="stable")[:, :k]
    sims = np.take_along_axis(s, order, axis=1)
    return sims, order


def pruned_knn_reference(q, db, pivots, k: int):
    """LAESA-style pruned exact kNN, scalar reference (paper's machinery).

    Per query: seed the candidate heap with the first k database points, then
    for each remaining point first test the pivot upper bound (Eq. 13, min
    over pivots); only if it exceeds the current k-th best similarity is the
    exact similarity computed.  Returns (sims, idx, exact_fraction) where
    exact_fraction is the fraction of database points whose exact similarity
    had to be computed (the paper's "pruning power" metric, lower = better).
    """
    qn, dbn, pn = normalize(q), normalize(db), normalize(pivots)
    qp = qn @ pn.T                     # [m, P]
    dp = dbn @ pn.T                    # [n, P]
    m, n = qn.shape[0], dbn.shape[0]
    sims_out = np.full((m, k), -np.inf)
    idx_out = np.zeros((m, k), np.int64)
    exact = 0
    for i in range(m):
        cand = []                       # list of (sim, idx)
        for j in range(n):
            if len(cand) >= k:
                tau = cand[k - 1][0]
                ub = np.min(ub_mult(qp[i], dp[j]))
                if ub < tau:            # Eq. 13 prune: cannot beat k-th best
                    continue
            s = float(qn[i] @ dbn[j])
            exact += 1
            cand.append((s, j))
            cand.sort(key=lambda t: (-t[0], t[1]))
            cand = cand[:k]
        sims_out[i] = [c[0] for c in cand]
        idx_out[i] = [c[1] for c in cand]
    return sims_out, idx_out, exact / (m * n)
