"""Triangle-inequality bounds for Cosine similarity (Schubert, SISAP 2021).

All functions are elementwise over arrays of *similarities*:

    a = sim(x, z)    b = sim(z, y)        a, b in [-1, 1]

and return a bound on ``sim(x, y)``.  Equation numbers follow the paper.

The recommended pair (paper §5) is :func:`lb_mult` / :func:`ub_mult`::

    sim(x,y) >= a*b - sqrt((1-a^2)(1-b^2))      (Eq. 10, tight)
    sim(x,y) <= a*b + sqrt((1-a^2)(1-b^2))      (Eq. 13, tight)

These are mathematically equivalent to the arccos forms (Eq. 9) but avoid
trigonometric calls entirely — on TPU the arccos form would lower to slow VPU
polynomial approximations while the Mult form is pure mul/sub/rsqrt.

Numerical notes (paper §4.2): the ``1 - sim^2`` radicands are clamped at zero.
When cancellation would occur (sim -> 1) the sqrt term itself vanishes, so the
clamp does not change the value, it only guards against producing NaN from a
tiny negative radicand in floating point.

Every function here has a float64 numpy oracle twin in :mod:`repro.core.ref`;
the property tests in ``tests/test_bounds.py`` check validity (bounds never
cross the true similarity computed from explicit vectors) and the ordering
relations of the paper's Fig. 3.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import Array

__all__ = [
    "lb_euclid",
    "lb_euclid_fast",
    "lb_arccos",
    "lb_mult",
    "lb_mult_fast1",
    "lb_mult_fast2",
    "ub_mult",
    "ub_euclid",
    "ub_arccos",
    "pivot_lower_bound",
    "pivot_upper_bound",
    "LOWER_BOUNDS",
    "JOINT_SLACK",
    "ub_joint",
    "joint_row_upper_bound",
    "BOUND_PROVIDERS",
    "register_bound_provider",
    "block_upper_provider",
]


def _radicand(s: Array) -> Array:
    """``max(0, 1 - s^2)`` — clamped radicand, see module docstring."""
    return jnp.maximum(0.0, 1.0 - s * s)


def lb_euclid(a: Array, b: Array) -> Array:
    """Eq. (7): lower bound via the Euclidean / chord-length metric.

    ``sim(x,y) >= a + b - 1 - 2*sqrt((1-a)(1-b))``
    """
    rad = jnp.maximum(0.0, (1.0 - a) * (1.0 - b))
    return a + b - 1.0 - 2.0 * jnp.sqrt(rad)


def lb_euclid_fast(a: Array, b: Array) -> Array:
    """Eq. (8) "Eucl-LB": sqrt-free approximation of Eq. (7); loosest bound.

    ``sim(x,y) >= a + b + 2*min(a,b) - 3``
    """
    return a + b + 2.0 * jnp.minimum(a, b) - 3.0


def lb_arccos(a: Array, b: Array) -> Array:
    """Eq. (9): tight lower bound via arc length (angles add on the sphere).

    ``sim(x,y) >= cos(arccos(a) + arccos(b))``

    Mathematically identical to :func:`lb_mult`; kept for the reproduction of
    the paper's Table 2 / Fig. 5 comparisons.  Inputs are clipped to [-1, 1]
    so ``arccos`` stays defined under fp roundoff.
    """
    ca = jnp.arccos(jnp.clip(a, -1.0, 1.0))
    cb = jnp.arccos(jnp.clip(b, -1.0, 1.0))
    return jnp.cos(ca + cb)


def lb_mult(a: Array, b: Array) -> Array:
    """Eq. (10) "Mult" (recommended): tight, trigonometry-free lower bound.

    ``sim(x,y) >= a*b - sqrt((1-a^2)(1-b^2))``
    """
    return a * b - jnp.sqrt(_radicand(a) * _radicand(b))


def lb_mult_fast1(a: Array, b: Array) -> Array:
    """Eq. (11) "Mult-LB1": sqrt-free; best of the simplified bounds.

    ``sim(x,y) >= a*b + min(a,b)^2 - 1``
    """
    m = jnp.minimum(a, b)
    return a * b + m * m - 1.0


def lb_mult_fast2(a: Array, b: Array) -> Array:
    """Eq. (12) "Mult-LB2": sqrt-free; strictly inferior to Eq. (11).

    ``sim(x,y) >= 2*a*b - |a - b| - 1``
    """
    return 2.0 * a * b - jnp.abs(a - b) - 1.0


def ub_mult(a: Array, b: Array) -> Array:
    """Eq. (13): tight upper bound — the pruning workhorse for kNN search.

    ``sim(x,y) <= a*b + sqrt((1-a^2)(1-b^2))``
    """
    return a * b + jnp.sqrt(_radicand(a) * _radicand(b))


def ub_euclid(a: Array, b: Array) -> Array:
    """Upper bound via the chord metric (reverse of Eq. 7; looser than Eq. 13).

    From ``d_sqrtcos(x,y) >= |d(x,z) - d(z,y)|``:
    ``sim(x,y) <= a + b - 1 + 2*sqrt((1-a)(1-b))``
    """
    rad = jnp.maximum(0.0, (1.0 - a) * (1.0 - b))
    return a + b - 1.0 + 2.0 * jnp.sqrt(rad)


def ub_arccos(a: Array, b: Array) -> Array:
    """Arccos form of the upper bound: ``cos(|arccos(a) - arccos(b)|)``."""
    ca = jnp.arccos(jnp.clip(a, -1.0, 1.0))
    cb = jnp.arccos(jnp.clip(b, -1.0, 1.0))
    return jnp.cos(jnp.abs(ca - cb))


# ---------------------------------------------------------------------------
# Pivot-set (LAESA-style) bounds: combine bounds over several reference points.
# ---------------------------------------------------------------------------

def pivot_lower_bound(qp: Array, dp: Array, *, axis: int = -1) -> Array:
    """Best (largest) Eq. 10 lower bound over a set of pivots.

    Args:
      qp: similarities of the query to each pivot, shape ``[..., P]``.
      dp: similarities of the database object to each pivot, ``[..., P]``.
      axis: the pivot axis to reduce over.

    Returns ``max_p lb_mult(qp_p, dp_p)`` — every pivot yields a valid lower
    bound, so the max is a valid (and the tightest available) lower bound.
    """
    return jnp.max(lb_mult(qp, dp), axis=axis)


def pivot_upper_bound(qp: Array, dp: Array, *, axis: int = -1) -> Array:
    """Tightest (smallest) Eq. 13 upper bound over a set of pivots.

    ``min_p ub_mult(qp_p, dp_p)`` — the pruning rule of the block index:
    a candidate (or block) whose pivot upper bound falls below the running
    k-th best similarity cannot be a true neighbor.
    """
    return jnp.min(ub_mult(qp, dp), axis=axis)


#: name -> fn map in the paper's Table 1 order (used by benchmarks/tests).
LOWER_BOUNDS = {
    "euclidean": lb_euclid,       # Eq. 7
    "eucl_lb": lb_euclid_fast,    # Eq. 8
    "arccos": lb_arccos,          # Eq. 9
    "mult": lb_mult,              # Eq. 10 (recommended)
    "mult_lb1": lb_mult_fast1,    # Eq. 11
    "mult_lb2": lb_mult_fast2,    # Eq. 12
}


# ---------------------------------------------------------------------------
# Joint multi-pivot (simplex / projection) upper bound.
#
# With an orthonormalized pivot basis U (see
# :func:`repro.core.pivots.orthonormal_pivot_basis`), the coordinates
# alpha = U q and beta = U y of two unit vectors satisfy
#
#     sim(q, y) <= <alpha, beta> + sqrt((1 - |alpha|^2)(1 - |beta|^2))
#
# because the residuals of q and y orthogonal to span(U) have norms
# sqrt(1 - |alpha|^2) and sqrt(1 - |beta|^2) and can at best be parallel.
# At one pivot this IS Eq. 13; at P = d it degenerates to the exact score.
# Validity for duplicate / dependent pivots is by the jittered-lift
# argument recorded in DESIGN.md §3.8.
# ---------------------------------------------------------------------------

#: Additive guard for float32 accumulation in the joint bound's dot
#: products.  The paper's single-pivot bounds need no slack (their clamped
#: radicands only remove NaN), but the joint bound sums up to d products,
#: so a few ulps of headroom keep it a true upper bound in fp32.
JOINT_SLACK = 3e-5


def ub_joint(t: Array, a_nsq: Array, b_nsq: Array) -> Array:
    """Joint projection upper bound from precomputed pieces.

    Args:
      t: ``<alpha, beta>`` inner products of pivot-basis coordinates.
      a_nsq: ``|alpha|^2`` (must already be clamped to ``<= 1``).
      b_nsq: ``|beta|^2`` (likewise).
    """
    rad = jnp.maximum(0.0, 1.0 - a_nsq) * jnp.maximum(0.0, 1.0 - b_nsq)
    return t + jnp.sqrt(rad)


def joint_row_upper_bound(
    alpha: Array, beta: Array, beta_nsq: Array, *, slack: float = JOINT_SLACK
) -> Array:
    """Per-(query, row) joint bound table.

    Args:
      alpha: [M, J] query coordinates in the pivot basis.
      beta:  [N, J] database-row coordinates.
      beta_nsq: [N] precomputed ``|beta|^2`` at this prefix depth.

    Returns [M, N] float32 upper bounds on ``sim(q_m, y_n)``.
    """
    t = alpha @ beta.T
    a_nsq = jnp.minimum(jnp.sum(alpha * alpha, axis=-1), 1.0)
    b_nsq = jnp.minimum(beta_nsq, 1.0)
    return ub_joint(t, a_nsq[:, None], b_nsq[None, :]) + slack


# ---------------------------------------------------------------------------
# Bound-provider contract.
#
# A provider maps (index, qn, qp, n_pivots) -> [M, NB] per-block upper
# bounds.  ``eq13`` is the classic single-formula interval bound (already
# intersected over the index's pivot-similarity intervals); ``eq13_multi``
# additionally intersects the joint n_pivots-deep projection cap — the min
# of valid upper bounds is a valid upper bound, so validity is inherited
# pointwise.  The registry keeps the family pluggable (e.g. a future
# Ptolemaic instance) without the engine knowing any formula.
# ---------------------------------------------------------------------------

#: name -> provider(index, qn, qp, n_pivots) -> [M, NB] block upper bounds.
BOUND_PROVIDERS: dict = {}


def register_bound_provider(name: str):
    """Decorator: register a block upper-bound provider under ``name``."""

    def deco(fn):
        BOUND_PROVIDERS[name] = fn
        return fn

    return deco


def block_upper_provider(name: str):
    """Look up a registered bound provider (KeyError lists known names)."""
    try:
        return BOUND_PROVIDERS[name]
    except KeyError:
        raise KeyError(
            f"unknown bound provider {name!r}; known: {sorted(BOUND_PROVIDERS)}"
        ) from None


@register_bound_provider("eq13")
def _eq13_provider(index, qn: Array, qp: Array, n_pivots: int = 0) -> Array:
    """Interval Eq. 13 bound, intersected over the index's pivots."""
    from repro.kernels import ref as kref  # local: keep core import-light

    return kref.block_bounds(qp, index.dp_min, index.dp_max)


@register_bound_provider("eq13_multi")
def _eq13_multi_provider(index, qn: Array, qp: Array, n_pivots: int) -> Array:
    """Eq. 13 intervals intersected with the joint n_pivots projection cap."""
    from repro.core.index import multipivot_block_cap  # local: avoid cycle
    from repro.kernels import ref as kref

    base = kref.block_bounds(qp, index.dp_min, index.dp_max)
    if n_pivots <= 0 or index.ortho is None:
        return base
    return jnp.minimum(base, multipivot_block_cap(index, qn, n_pivots=n_pivots))
