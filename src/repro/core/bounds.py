"""Triangle-inequality bounds for Cosine similarity (Schubert, SISAP 2021).

All functions are elementwise over arrays of *similarities*:

    a = sim(x, z)    b = sim(z, y)        a, b in [-1, 1]

and return a bound on ``sim(x, y)``.  Equation numbers follow the paper.

The recommended pair (paper §5) is :func:`lb_mult` / :func:`ub_mult`::

    sim(x,y) >= a*b - sqrt((1-a^2)(1-b^2))      (Eq. 10, tight)
    sim(x,y) <= a*b + sqrt((1-a^2)(1-b^2))      (Eq. 13, tight)

These are mathematically equivalent to the arccos forms (Eq. 9) but avoid
trigonometric calls entirely — on TPU the arccos form would lower to slow VPU
polynomial approximations while the Mult form is pure mul/sub/rsqrt.

Numerical notes (paper §4.2): the ``1 - sim^2`` radicands are clamped at zero.
When cancellation would occur (sim -> 1) the sqrt term itself vanishes, so the
clamp does not change the value, it only guards against producing NaN from a
tiny negative radicand in floating point.

Every function here has a float64 numpy oracle twin in :mod:`repro.core.ref`;
the property tests in ``tests/test_bounds.py`` check validity (bounds never
cross the true similarity computed from explicit vectors) and the ordering
relations of the paper's Fig. 3.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import Array

__all__ = [
    "lb_euclid",
    "lb_euclid_fast",
    "lb_arccos",
    "lb_mult",
    "lb_mult_fast1",
    "lb_mult_fast2",
    "ub_mult",
    "ub_euclid",
    "ub_arccos",
    "pivot_lower_bound",
    "pivot_upper_bound",
    "LOWER_BOUNDS",
]


def _radicand(s: Array) -> Array:
    """``max(0, 1 - s^2)`` — clamped radicand, see module docstring."""
    return jnp.maximum(0.0, 1.0 - s * s)


def lb_euclid(a: Array, b: Array) -> Array:
    """Eq. (7): lower bound via the Euclidean / chord-length metric.

    ``sim(x,y) >= a + b - 1 - 2*sqrt((1-a)(1-b))``
    """
    rad = jnp.maximum(0.0, (1.0 - a) * (1.0 - b))
    return a + b - 1.0 - 2.0 * jnp.sqrt(rad)


def lb_euclid_fast(a: Array, b: Array) -> Array:
    """Eq. (8) "Eucl-LB": sqrt-free approximation of Eq. (7); loosest bound.

    ``sim(x,y) >= a + b + 2*min(a,b) - 3``
    """
    return a + b + 2.0 * jnp.minimum(a, b) - 3.0


def lb_arccos(a: Array, b: Array) -> Array:
    """Eq. (9): tight lower bound via arc length (angles add on the sphere).

    ``sim(x,y) >= cos(arccos(a) + arccos(b))``

    Mathematically identical to :func:`lb_mult`; kept for the reproduction of
    the paper's Table 2 / Fig. 5 comparisons.  Inputs are clipped to [-1, 1]
    so ``arccos`` stays defined under fp roundoff.
    """
    ca = jnp.arccos(jnp.clip(a, -1.0, 1.0))
    cb = jnp.arccos(jnp.clip(b, -1.0, 1.0))
    return jnp.cos(ca + cb)


def lb_mult(a: Array, b: Array) -> Array:
    """Eq. (10) "Mult" (recommended): tight, trigonometry-free lower bound.

    ``sim(x,y) >= a*b - sqrt((1-a^2)(1-b^2))``
    """
    return a * b - jnp.sqrt(_radicand(a) * _radicand(b))


def lb_mult_fast1(a: Array, b: Array) -> Array:
    """Eq. (11) "Mult-LB1": sqrt-free; best of the simplified bounds.

    ``sim(x,y) >= a*b + min(a,b)^2 - 1``
    """
    m = jnp.minimum(a, b)
    return a * b + m * m - 1.0


def lb_mult_fast2(a: Array, b: Array) -> Array:
    """Eq. (12) "Mult-LB2": sqrt-free; strictly inferior to Eq. (11).

    ``sim(x,y) >= 2*a*b - |a - b| - 1``
    """
    return 2.0 * a * b - jnp.abs(a - b) - 1.0


def ub_mult(a: Array, b: Array) -> Array:
    """Eq. (13): tight upper bound — the pruning workhorse for kNN search.

    ``sim(x,y) <= a*b + sqrt((1-a^2)(1-b^2))``
    """
    return a * b + jnp.sqrt(_radicand(a) * _radicand(b))


def ub_euclid(a: Array, b: Array) -> Array:
    """Upper bound via the chord metric (reverse of Eq. 7; looser than Eq. 13).

    From ``d_sqrtcos(x,y) >= |d(x,z) - d(z,y)|``:
    ``sim(x,y) <= a + b - 1 + 2*sqrt((1-a)(1-b))``
    """
    rad = jnp.maximum(0.0, (1.0 - a) * (1.0 - b))
    return a + b - 1.0 + 2.0 * jnp.sqrt(rad)


def ub_arccos(a: Array, b: Array) -> Array:
    """Arccos form of the upper bound: ``cos(|arccos(a) - arccos(b)|)``."""
    ca = jnp.arccos(jnp.clip(a, -1.0, 1.0))
    cb = jnp.arccos(jnp.clip(b, -1.0, 1.0))
    return jnp.cos(jnp.abs(ca - cb))


# ---------------------------------------------------------------------------
# Pivot-set (LAESA-style) bounds: combine bounds over several reference points.
# ---------------------------------------------------------------------------

def pivot_lower_bound(qp: Array, dp: Array, *, axis: int = -1) -> Array:
    """Best (largest) Eq. 10 lower bound over a set of pivots.

    Args:
      qp: similarities of the query to each pivot, shape ``[..., P]``.
      dp: similarities of the database object to each pivot, ``[..., P]``.
      axis: the pivot axis to reduce over.

    Returns ``max_p lb_mult(qp_p, dp_p)`` — every pivot yields a valid lower
    bound, so the max is a valid (and the tightest available) lower bound.
    """
    return jnp.max(lb_mult(qp, dp), axis=axis)


def pivot_upper_bound(qp: Array, dp: Array, *, axis: int = -1) -> Array:
    """Tightest (smallest) Eq. 13 upper bound over a set of pivots.

    ``min_p ub_mult(qp_p, dp_p)`` — the pruning rule of the block index:
    a candidate (or block) whose pivot upper bound falls below the running
    k-th best similarity cannot be a true neighbor.
    """
    return jnp.min(ub_mult(qp, dp), axis=axis)


#: name -> fn map in the paper's Table 1 order (used by benchmarks/tests).
LOWER_BOUNDS = {
    "euclidean": lb_euclid,       # Eq. 7
    "eucl_lb": lb_euclid_fast,    # Eq. 8
    "arccos": lb_arccos,          # Eq. 9
    "mult": lb_mult,              # Eq. 10 (recommended)
    "mult_lb1": lb_mult_fast1,    # Eq. 11
    "mult_lb2": lb_mult_fast2,    # Eq. 12
}
