"""Online mutation for a live :class:`~repro.search.SearchEngine`.

The block index (DESIGN.md §3.1) is a static pytree built for a frozen
corpus; this module makes it *mutable* without giving up the bound
machinery.  The trick is that every structure the search paths consult is
valid under **conservative widening** (DESIGN.md §3.9):

* inserts write rows into free padded slots (block tails, or freshly
  appended all-padding blocks) and only *loosen* the per-block pivot
  intervals ``dp_min/dp_max`` and the tree's node caches — a looser
  interval can only make the Eq. 13 upper bound larger, so bounds remain
  true upper bounds and search stays exact;
* deletes are tombstones: flip ``valid`` off and leave every interval
  untouched — stale-but-wide bounds never exclude a live row, and all
  backends mask scores by per-row validity *before* top-k, so a
  tombstoned row can never be returned.

Widening degrades pruning power over time (intervals only grow,
tombstones keep paying their bound checks), so the handle tracks a
*pruning-decay estimate* — mutated rows as a fraction of the corpus size
at the last (re)build — and triggers a deferred :meth:`reoptimize`
(full rebuild: repack live rows, reselect pivots, tighten everything)
once it crosses a threshold.

Mutations are classified by whether the pytree *shapes* change:

* shape-stable (tail inserts, deletes): the new index flows as an
  argument through the engine's cached fused executables — zero
  retraces (the dispatch key's ``index_epoch`` is unchanged);
* shape-changing (appended blocks, reoptimize): the engine bumps
  ``index_epoch`` and drops its dispatch caches, so the next search
  pays exactly one retrace at the new shape.

Sharded (multi-host / multi-device) engines are **not** mutable — each
process only holds its local shard and a cross-host insert would need a
placement protocol; :class:`MutableIndex` refuses them up front (build a
fresh sharded engine via ``SearchEngine.build(distributed=True)``
instead).

External row ids are stable across the handle's lifetime: the ids
returned by :meth:`insert` (and the original ``0..n-1`` corpus ids)
survive :meth:`reoptimize` unchanged, so id-aligned side tables (e.g.
the kNN-LM value array, :mod:`repro.serve.knnlm`) never need remapping.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.index import BlockIndex, build_index

__all__ = ["MutableIndex"]


def _append_blocks(index: BlockIndex, n_add: int) -> BlockIndex:
    """Grow the index by ``n_add`` all-padding blocks (neutral ``[0, 0]``
    intervals, ``valid`` False, ``row_ids`` -1) — a pure shape change; no
    live row moves."""
    bs = index.block_size
    nr = n_add * bs
    p = index.dp.shape[1]
    zrows = jnp.zeros((nr, index.db.shape[1]), index.db.dtype)
    zdp = jnp.zeros((nr, p), index.dp.dtype)
    new = index._replace(
        db=jnp.concatenate([index.db, zrows]),
        dp=jnp.concatenate([index.dp, zdp]),
        valid=jnp.concatenate([index.valid,
                               jnp.zeros((nr,), index.valid.dtype)]),
        row_ids=jnp.concatenate([index.row_ids,
                                 jnp.full((nr,), -1, jnp.int32)]),
        dp_min=jnp.concatenate([index.dp_min,
                                jnp.zeros((n_add, p), index.dp_min.dtype)]),
        dp_max=jnp.concatenate([index.dp_max,
                                jnp.zeros((n_add, p), index.dp_max.dtype)]),
    )
    if index.beta is not None:
        new = new._replace(
            beta=jnp.concatenate([index.beta, zdp]),
            beta_nsq=jnp.concatenate([index.beta_nsq, zdp]),
        )
    return new


class MutableIndex:
    """Insert/delete/reoptimize handle over a ``SearchEngine``'s index.

    Obtain one via :meth:`SearchEngine.online`; do not construct two
    handles over the same engine (the handle owns host-side mirrors —
    the free-slot list and the external-id → slot map — that must stay
    in sync with the device arrays).

    Args:
      engine: the engine to mutate (single-shard backends only).
      reoptimize_threshold: trigger a full rebuild once
        ``decay_estimate`` (mutated rows / corpus size at last build)
        reaches this value.
      auto_reoptimize: if False, never rebuild implicitly — the caller
        watches ``decay_estimate`` and calls :meth:`reoptimize` at a
        convenient moment (e.g. off the serving hot path).
    """

    def __init__(self, engine, *, reoptimize_threshold: float = 0.5,
                 auto_reoptimize: bool = True):
        index = engine.index
        if engine.backend_name == "sharded" or index.db.ndim != 2:
            raise NotImplementedError(
                "online mutation is not supported for sharded engines: each "
                "process holds only its local shard, and an insert would "
                "need a cross-host placement protocol (see repro.core."
                "distributed). Rebuild with SearchEngine.build(..., "
                "distributed=True), or mutate a single-shard engine.")
        self.engine = engine
        self.reoptimize_threshold = float(reoptimize_threshold)
        self.auto_reoptimize = bool(auto_reoptimize)
        #: total mutation calls applied through this handle (also
        #: surfaced as ``SearchStats.generation``)
        self.generation = 0
        self._mutations_since_opt = 0
        row_ids = np.asarray(index.row_ids)
        self._id_pos = {int(r): int(p) for p, r in enumerate(row_ids)
                        if r >= 0}
        # descending so list.pop() hands out the lowest free slot first
        # (keeps inserts packed toward block fronts)
        self._free = sorted(
            np.flatnonzero(row_ids < 0).tolist(), reverse=True)
        self._next_id = max(self._id_pos, default=-1) + 1
        self._rows_at_opt = max(1, len(self._id_pos))

    # ------------------------------------------------------------- inspect
    @property
    def n_live(self) -> int:
        """Number of live (searchable) rows."""
        return len(self._id_pos)

    @property
    def decay_estimate(self) -> float:
        """Mutated rows since the last (re)build, as a fraction of the
        corpus size at that build — the proxy for how much pruning power
        the widened intervals have lost (DESIGN.md §3.9)."""
        return self._mutations_since_opt / self._rows_at_opt

    def __contains__(self, row_id: int) -> bool:
        return int(row_id) in self._id_pos

    # -------------------------------------------------------------- insert
    def insert(self, rows) -> list[int]:
        """Insert ``rows`` ([n, d] or [d]); returns their external ids.

        Rows are normalized here (cosine search stores unit vectors).
        Free padded slots are filled first; if they run out, all-padding
        blocks are appended (a shape change — the next search retraces
        once).  Affected block intervals, joint-bound table rows and —
        when the tree backend has already built one — the tree's
        root-to-leaf node caches are conservatively widened in one fused
        scatter per table.
        """
        rows64 = np.asarray(rows, np.float64)
        if rows64.ndim == 1:
            rows64 = rows64[None, :]
        n_new = rows64.shape[0]
        if n_new == 0:
            return []
        eng = self.engine
        index = eng.index
        if rows64.shape[1] != index.db.shape[1]:
            raise ValueError(
                f"inserted rows have dim {rows64.shape[1]}, "
                f"index has dim {index.db.shape[1]}")
        norms = np.linalg.norm(rows64, axis=1, keepdims=True)
        rows64 = rows64 / np.where(norms == 0.0, 1.0, norms)

        bs = index.block_size
        shape_changed = False
        if len(self._free) < n_new:
            n_add = -(-(n_new - len(self._free)) // bs)
            old_slots = index.db.shape[0]
            index = _append_blocks(index, n_add)
            self._free = sorted(
                self._free + list(range(old_slots, old_slots + n_add * bs)),
                reverse=True)
            shape_changed = True
        pos = np.array([self._free.pop() for _ in range(n_new)], np.int64)
        ids = list(range(self._next_id, self._next_id + n_new))

        posj = jnp.asarray(pos, jnp.int32)
        blkj = jnp.asarray(pos // bs, jnp.int32)
        rows_n = jnp.asarray(rows64, jnp.float32)
        # same fp32 product the flat search paths compare against, so the
        # widened intervals bound exactly what the kernels compute
        dp_new = rows_n @ index.pivots.T                     # [n_new, P]
        new_index = index._replace(
            db=index.db.at[posj].set(rows_n),
            dp=index.dp.at[posj].set(dp_new),
            valid=index.valid.at[posj].set(True),
            row_ids=index.row_ids.at[posj].set(
                jnp.asarray(ids, jnp.int32)),
            dp_min=index.dp_min.at[blkj].min(dp_new),
            dp_max=index.dp_max.at[blkj].max(dp_new),
        )
        if index.ortho is not None:
            # stored basis is fp32; the upcast error vs the build-time fp64
            # basis is ~1e-7 per coordinate, absorbed by JOINT_SLACK
            u64 = np.asarray(index.ortho, np.float64)
            beta64 = rows64 @ u64.T
            bnsq64 = np.cumsum(beta64 * beta64, axis=1)
            new_index = new_index._replace(
                beta=index.beta.at[posj].set(
                    jnp.asarray(beta64, jnp.float32)),
                beta_nsq=index.beta_nsq.at[posj].set(
                    jnp.asarray(bnsq64, jnp.float32)),
            )

        tree = tvn = None
        if not shape_changed and eng._tree_index is not None:
            from repro.search.tree import widen_tree
            tree = widen_tree(eng._tree_index, new_index, blkj, dp_new)
            tvn = tree.n_valid_nodes

        for i, p in zip(ids, pos):
            self._id_pos[i] = int(p)
        self._next_id += n_new
        self.generation += 1
        self._mutations_since_opt += n_new
        eng._apply_mutation(new_index, n_valid=len(self._id_pos),
                            shape_changed=shape_changed, tree=tree,
                            tree_valid_nodes=tvn)
        self._maybe_reoptimize()
        return ids

    # -------------------------------------------------------------- delete
    def delete(self, ids) -> None:
        """Tombstone-delete rows by external id.

        ``valid`` flips off and ``row_ids`` goes -1; the block/tree
        intervals stay conservatively wide (a bound that is too loose is
        still a bound), and every backend masks by per-row validity
        before top-k, so deleted rows are unreachable immediately.
        Raises ``KeyError`` (before any state changes) if any id is not
        live.
        """
        if isinstance(ids, (int, np.integer)):
            ids = [ids]
        ids = [int(i) for i in ids]
        if not ids:
            return
        bad = [i for i in ids if i not in self._id_pos]
        if bad:
            raise KeyError(
                f"row ids {bad} are not in the live set (never inserted, "
                f"or already deleted)")
        if len(set(ids)) != len(ids):
            raise KeyError(f"duplicate row ids in delete: {ids}")
        pos = [self._id_pos.pop(i) for i in ids]
        posj = jnp.asarray(pos, jnp.int32)
        index = self.engine.index
        new_index = index._replace(
            valid=index.valid.at[posj].set(False),
            row_ids=index.row_ids.at[posj].set(-1),
        )
        self._free = sorted(self._free + pos, reverse=True)
        self.generation += 1
        self._mutations_since_opt += len(pos)
        self.engine._apply_mutation(new_index,
                                    n_valid=len(self._id_pos),
                                    shape_changed=False)
        self._maybe_reoptimize()

    # ---------------------------------------------------------- reoptimize
    def reoptimize(self) -> None:
        """Full rebuild: repack live rows, reselect pivots, tighten every
        interval.  External ids are preserved (remapped through the new
        build's permutation).  A shape change: caches drop, next search
        retraces once."""
        eng = self.engine
        index = eng.index
        row_ids = np.asarray(index.row_ids)
        live = np.flatnonzero(row_ids >= 0)
        self._rows_at_opt = max(1, live.size)
        self._mutations_since_opt = 0
        self.generation += 1
        if live.size == 0:
            # nothing to repack; keep the (all-padding) index as is
            return
        ext_ids = row_ids[live].astype(np.int32)
        rows = np.asarray(index.db)[live]
        new = build_index(rows, n_pivots=int(index.pivots.shape[0]),
                          block_size=index.block_size)
        # the fresh build numbers rows 0..n_live-1; map back to external ids
        nr = np.asarray(new.row_ids)
        mapped = np.where(nr >= 0,
                          ext_ids[np.clip(nr, 0, live.size - 1)],
                          -1).astype(np.int32)
        new = new._replace(row_ids=jnp.asarray(mapped))
        self._id_pos = {int(r): int(p) for p, r in enumerate(mapped)
                        if r >= 0}
        self._free = sorted(
            np.flatnonzero(mapped < 0).tolist(), reverse=True)
        eng._apply_mutation(new, n_valid=live.size, shape_changed=True)

    def _maybe_reoptimize(self) -> None:
        if (self.auto_reoptimize
                and self.decay_estimate >= self.reoptimize_threshold):
            self.reoptimize()
