"""Online mutation for a live :class:`~repro.search.SearchEngine`.

The block index (DESIGN.md §3.1) is a static pytree built for a frozen
corpus; this module makes it *mutable* without giving up the bound
machinery.  The trick is that every structure the search paths consult is
valid under **conservative widening** (DESIGN.md §3.9):

* inserts write rows into free padded slots (block tails, or freshly
  appended all-padding blocks) and only *loosen* the per-block pivot
  intervals ``dp_min/dp_max`` and the tree's node caches — a looser
  interval can only make the Eq. 13 upper bound larger, so bounds remain
  true upper bounds and search stays exact;
* deletes are tombstones: flip ``valid`` off and leave every interval
  untouched — stale-but-wide bounds never exclude a live row, and all
  backends mask scores by per-row validity *before* top-k, so a
  tombstoned row can never be returned.

Widening degrades pruning power over time (intervals only grow,
tombstones keep paying their bound checks), so the handle tracks a
*pruning-decay estimate* — mutated rows as a fraction of the corpus size
at the last (re)build — and triggers a deferred :meth:`reoptimize`
(full rebuild: repack live rows, reselect pivots, tighten everything)
once it crosses a threshold.

Mutations are classified by whether the pytree *shapes* change:

* shape-stable (tail inserts, deletes): the new index flows as an
  argument through the engine's cached fused executables — zero
  retraces (the dispatch key's ``index_epoch`` is unchanged);
* shape-changing (appended blocks, reoptimize): the engine bumps
  ``index_epoch`` and drops its dispatch caches, so the next search
  pays exactly one retrace at the new shape.

Sharded (multi-host / multi-device) engines are mutable too, through
:class:`ShardedMutableIndex` (``SearchEngine.online()`` picks the right
handle automatically): external ids come from a replicated monotone
counter, a deterministic placement protocol maps each id to an owning
shard as a pure function of replicated host state (so every process
decides identically with no extra collectives — DESIGN.md §3.10), and the
widening machinery above is applied per shard through vmapped masked
scatters (:func:`repro.core.distributed.make_sharded_mutation`).

External row ids are stable across the handle's lifetime: the ids
returned by :meth:`insert` (and the original ``0..n-1`` corpus ids)
survive :meth:`reoptimize` unchanged, so id-aligned side tables (e.g.
the kNN-LM value array, :mod:`repro.serve.knnlm`) never need remapping.
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from repro.core.index import BlockIndex, build_index

__all__ = ["MutableIndex", "ShardedMutableIndex"]


def _append_blocks(index: BlockIndex, n_add: int) -> BlockIndex:
    """Grow the index by ``n_add`` all-padding blocks (``valid`` False,
    ``row_ids`` -1) — a pure shape change; no live row moves.

    New blocks carry the *empty-interval sentinel* ``dp_min = +inf,
    dp_max = -inf``: every bound path maps an inverted interval to a -inf
    upper bound (empty blocks prune unconditionally), and the insert
    scatter-min/max against the sentinel records the first rows' EXACT
    interval.  The old neutral ``[0, 0]`` seed permanently anchored every
    appended block's interval at zero — a block whose rows all sit in e.g.
    ``[0.6, 0.9]`` was stuck with the loose ``[0, 0.9]`` until reoptimize.
    """
    bs = index.block_size
    nr = n_add * bs
    p = index.dp.shape[1]
    zrows = jnp.zeros((nr, index.db.shape[1]), index.db.dtype)
    zdp = jnp.zeros((nr, p), index.dp.dtype)
    new = index._replace(
        db=jnp.concatenate([index.db, zrows]),
        dp=jnp.concatenate([index.dp, zdp]),
        valid=jnp.concatenate([index.valid,
                               jnp.zeros((nr,), index.valid.dtype)]),
        row_ids=jnp.concatenate([index.row_ids,
                                 jnp.full((nr,), -1, jnp.int32)]),
        dp_min=jnp.concatenate([index.dp_min,
                                jnp.full((n_add, p), jnp.inf,
                                         index.dp_min.dtype)]),
        dp_max=jnp.concatenate([index.dp_max,
                                jnp.full((n_add, p), -jnp.inf,
                                         index.dp_max.dtype)]),
    )
    if index.beta is not None:
        new = new._replace(
            beta=jnp.concatenate([index.beta, zdp]),
            beta_nsq=jnp.concatenate([index.beta_nsq, zdp]),
        )
    return new


class MutableIndex:
    """Insert/delete/reoptimize handle over a ``SearchEngine``'s index.

    Obtain one via :meth:`SearchEngine.online`; do not construct two
    handles over the same engine (the handle owns host-side mirrors —
    the free-slot list and the external-id → slot map — that must stay
    in sync with the device arrays).

    Args:
      engine: the engine to mutate (single-shard backends only).
      reoptimize_threshold: trigger a full rebuild once
        ``decay_estimate`` (mutated rows / corpus size at last build)
        reaches this value.
      auto_reoptimize: if False, never rebuild implicitly — the caller
        watches ``decay_estimate`` and calls :meth:`reoptimize` at a
        convenient moment (e.g. off the serving hot path).
    """

    def __init__(self, engine, *, reoptimize_threshold: float = 0.5,
                 auto_reoptimize: bool = True):
        index = engine.index
        if engine.backend_name == "sharded" or index.db.ndim != 2:
            raise TypeError(
                "MutableIndex serves flat single-shard engines; sharded "
                "engines are mutated through ShardedMutableIndex — "
                "engine.online() picks the right handle automatically")
        self.engine = engine
        self.reoptimize_threshold = float(reoptimize_threshold)
        self.auto_reoptimize = bool(auto_reoptimize)
        #: total mutation calls applied through this handle (also
        #: surfaced as ``SearchStats.generation``)
        self.generation = 0
        self._mutations_since_opt = 0
        row_ids = np.asarray(index.row_ids)
        self._id_pos = {int(r): int(p) for p, r in enumerate(row_ids)
                        if r >= 0}
        # descending so list.pop() hands out the lowest free slot first
        # (keeps inserts packed toward block fronts)
        self._free = sorted(
            np.flatnonzero(row_ids < 0).tolist(), reverse=True)
        self._next_id = max(self._id_pos, default=-1) + 1
        self._rows_at_opt = max(1, len(self._id_pos))

    # ------------------------------------------------------------- inspect
    @property
    def n_live(self) -> int:
        """Number of live (searchable) rows."""
        return len(self._id_pos)

    @property
    def decay_estimate(self) -> float:
        """Mutated rows since the last (re)build, as a fraction of the
        corpus size at that build — the proxy for how much pruning power
        the widened intervals have lost (DESIGN.md §3.9)."""
        return self._mutations_since_opt / self._rows_at_opt

    def __contains__(self, row_id: int) -> bool:
        return int(row_id) in self._id_pos

    # -------------------------------------------------------------- insert
    def insert(self, rows) -> list[int]:
        """Insert ``rows`` ([n, d] or [d]); returns their external ids.

        Rows are normalized here (cosine search stores unit vectors).
        Free padded slots are filled first; if they run out, all-padding
        blocks are appended (a shape change — the next search retraces
        once).  Affected block intervals, joint-bound table rows and —
        when the tree backend has already built one — the tree's
        root-to-leaf node caches are conservatively widened in one fused
        scatter per table.
        """
        rows64 = np.asarray(rows, np.float64)
        if rows64.ndim == 1:
            rows64 = rows64[None, :]
        n_new = rows64.shape[0]
        if n_new == 0:
            return []
        eng = self.engine
        index = eng.index
        if rows64.shape[1] != index.db.shape[1]:
            raise ValueError(
                f"inserted rows have dim {rows64.shape[1]}, "
                f"index has dim {index.db.shape[1]}")
        norms = np.linalg.norm(rows64, axis=1, keepdims=True)
        rows64 = rows64 / np.where(norms == 0.0, 1.0, norms)

        bs = index.block_size
        shape_changed = False
        if len(self._free) < n_new:
            n_add = -(-(n_new - len(self._free)) // bs)
            old_slots = index.db.shape[0]
            index = _append_blocks(index, n_add)
            self._free = sorted(
                self._free + list(range(old_slots, old_slots + n_add * bs)),
                reverse=True)
            shape_changed = True
        pos = np.array([self._free.pop() for _ in range(n_new)], np.int64)
        ids = list(range(self._next_id, self._next_id + n_new))

        posj = jnp.asarray(pos, jnp.int32)
        blkj = jnp.asarray(pos // bs, jnp.int32)
        rows_n = jnp.asarray(rows64, jnp.float32)
        # same fp32 product the flat search paths compare against, so the
        # widened intervals bound exactly what the kernels compute
        dp_new = rows_n @ index.pivots.T                     # [n_new, P]
        new_index = index._replace(
            db=index.db.at[posj].set(rows_n),
            dp=index.dp.at[posj].set(dp_new),
            valid=index.valid.at[posj].set(True),
            row_ids=index.row_ids.at[posj].set(
                jnp.asarray(ids, jnp.int32)),
            dp_min=index.dp_min.at[blkj].min(dp_new),
            dp_max=index.dp_max.at[blkj].max(dp_new),
        )
        if index.ortho is not None:
            # stored basis is fp32; the upcast error vs the build-time fp64
            # basis is ~1e-7 per coordinate, absorbed by JOINT_SLACK
            u64 = np.asarray(index.ortho, np.float64)
            beta64 = rows64 @ u64.T
            bnsq64 = np.cumsum(beta64 * beta64, axis=1)
            new_index = new_index._replace(
                beta=index.beta.at[posj].set(
                    jnp.asarray(beta64, jnp.float32)),
                beta_nsq=index.beta_nsq.at[posj].set(
                    jnp.asarray(bnsq64, jnp.float32)),
            )

        tree = tvn = None
        if not shape_changed and eng._tree_index is not None:
            from repro.search.tree import widen_tree
            tree = widen_tree(eng._tree_index, new_index, blkj, dp_new)
            tvn = tree.n_valid_nodes

        for i, p in zip(ids, pos):
            self._id_pos[i] = int(p)
        self._next_id += n_new
        self.generation += 1
        self._mutations_since_opt += n_new
        eng._apply_mutation(new_index, n_valid=len(self._id_pos),
                            shape_changed=shape_changed, tree=tree,
                            tree_valid_nodes=tvn)
        self._maybe_reoptimize()
        return ids

    # -------------------------------------------------------------- delete
    def delete(self, ids) -> None:
        """Tombstone-delete rows by external id.

        ``valid`` flips off and ``row_ids`` goes -1; the block/tree
        intervals stay conservatively wide (a bound that is too loose is
        still a bound), and every backend masks by per-row validity
        before top-k, so deleted rows are unreachable immediately.
        Raises ``KeyError`` (before any state changes) if any id is not
        live.
        """
        if isinstance(ids, (int, np.integer)):
            ids = [ids]
        ids = [int(i) for i in ids]
        if not ids:
            return
        bad = [i for i in ids if i not in self._id_pos]
        if bad:
            raise KeyError(
                f"row ids {bad} are not in the live set (never inserted, "
                f"or already deleted)")
        if len(set(ids)) != len(ids):
            raise KeyError(f"duplicate row ids in delete: {ids}")
        pos = [self._id_pos.pop(i) for i in ids]
        posj = jnp.asarray(pos, jnp.int32)
        index = self.engine.index
        new_index = index._replace(
            valid=index.valid.at[posj].set(False),
            row_ids=index.row_ids.at[posj].set(-1),
        )
        self._free = sorted(self._free + pos, reverse=True)
        self.generation += 1
        self._mutations_since_opt += len(pos)
        self.engine._apply_mutation(new_index,
                                    n_valid=len(self._id_pos),
                                    shape_changed=False)
        self._maybe_reoptimize()

    # ---------------------------------------------------------- reoptimize
    def reoptimize(self) -> None:
        """Full rebuild: repack live rows, reselect pivots, tighten every
        interval.  External ids are preserved (remapped through the new
        build's permutation).  A shape change: caches drop, next search
        retraces once."""
        eng = self.engine
        index = eng.index
        row_ids = np.asarray(index.row_ids)
        live = np.flatnonzero(row_ids >= 0)
        self._rows_at_opt = max(1, live.size)
        self._mutations_since_opt = 0
        self.generation += 1
        if live.size == 0:
            # no live rows: still go through _apply_mutation with a clean
            # all-padding index (empty-interval sentinels, free pivots kept)
            # so the stale widened tree / dispatch caches drop and
            # index_epoch bumps exactly like every other reoptimize — an
            # early return here left the engine serving dead caches
            new = index._replace(
                db=jnp.zeros_like(index.db),
                dp=jnp.zeros_like(index.dp),
                valid=jnp.zeros_like(index.valid),
                row_ids=jnp.full_like(index.row_ids, -1),
                dp_min=jnp.full_like(index.dp_min, jnp.inf),
                dp_max=jnp.full_like(index.dp_max, -jnp.inf),
            )
            if index.beta is not None:
                new = new._replace(beta=jnp.zeros_like(index.beta),
                                   beta_nsq=jnp.zeros_like(index.beta_nsq))
            self._id_pos = {}
            self._free = list(range(index.db.shape[0] - 1, -1, -1))
            eng._apply_mutation(new, n_valid=0, shape_changed=True)
            return
        ext_ids = row_ids[live].astype(np.int32)
        rows = np.asarray(index.db)[live]
        new = build_index(rows, n_pivots=int(index.pivots.shape[0]),
                          block_size=index.block_size)
        # the fresh build numbers rows 0..n_live-1; map back to external ids
        nr = np.asarray(new.row_ids)
        mapped = np.where(nr >= 0,
                          ext_ids[np.clip(nr, 0, live.size - 1)],
                          -1).astype(np.int32)
        new = new._replace(row_ids=jnp.asarray(mapped))
        self._id_pos = {int(r): int(p) for p, r in enumerate(mapped)
                        if r >= 0}
        self._free = sorted(
            np.flatnonzero(mapped < 0).tolist(), reverse=True)
        eng._apply_mutation(new, n_valid=live.size, shape_changed=True)

    def _maybe_reoptimize(self) -> None:
        if (self.auto_reoptimize
                and self.decay_estimate >= self.reoptimize_threshold):
            self.reoptimize()


class ShardedMutableIndex(MutableIndex):
    """Insert/delete/reoptimize handle over a *sharded* ``SearchEngine``.

    Same public surface and widening semantics as :class:`MutableIndex`,
    plus the cross-host row-placement protocol (DESIGN.md §3.10):

    * every process mirrors the same host state — the id → (shard, slot)
      map and per-shard descending free lists, derived once from the
      replicated ``row_ids`` (:func:`~repro.core.distributed.
      replicated_row_ids`) — and the external-id counter is monotone over
      it, so id allocation is replicated by construction;
    * a new row's owning shard is a *pure function* of that state:
      round-robin by id (``id % S``), falling back to the shard with the
      most free slots (ties → lowest shard id) when the preferred tail is
      full, and appending one all-padding block to EVERY shard (stacked
      shapes stay uniform) when all tails are full.  Rows place one at a
      time so the free lists evolve deterministically — every process
      computes the identical placement with zero extra collectives;
    * the device apply is shard-local: uniform-width update operands are
      replicated and each shard's slice lands via vmapped masked scatters
      (:func:`~repro.core.distributed.make_sharded_mutation`), including
      per-shard interval widening, joint-table rows, and — when the
      sharded tree is live — per-shard ``widen_tree``.

    :meth:`reoptimize` repacks **within** shards (drop tombstones, restore
    angular block coherence, re-tighten every interval from live rows)
    under each shard's existing pivots; no row moves across shards and no
    pivot is reselected, which is what keeps the rebuild collective-free
    apart from the one ``row_ids`` re-replication.

    Multi-process contract: mutation calls must be made identically on
    every process (same rows, same order) — the same SPMD discipline
    every other call in a multi-host program already follows.
    """

    def __init__(self, engine, *, reoptimize_threshold: float = 0.5,
                 auto_reoptimize: bool = True):
        index = engine.index
        if index.db.ndim != 3 or engine.mesh is None:
            raise TypeError(
                "ShardedMutableIndex needs a shard-stacked index and a "
                "mesh; flat engines are mutated through MutableIndex — "
                "engine.online() picks the right handle automatically")
        from repro.core.distributed import (make_sharded_mutation,
                                            replicated_row_ids)
        self.engine = engine
        self.reoptimize_threshold = float(reoptimize_threshold)
        self.auto_reoptimize = bool(auto_reoptimize)
        self.generation = 0
        self._mutations_since_opt = 0
        self._ops = make_sharded_mutation(engine.mesh, engine.axis_names)
        self._sync_mirrors(replicated_row_ids(index, engine.mesh))
        self._next_id = max(self._id_pos, default=-1) + 1
        self._rows_at_opt = max(1, len(self._id_pos))

    def _sync_mirrors(self, row_ids: np.ndarray) -> None:
        """Rebuild the replicated host mirrors from a ``[S, n_pad]``
        ``row_ids`` copy: ``_id_pos`` maps external id → (shard, slot),
        ``_free[s]`` is shard ``s``'s free slots, descending so ``pop()``
        hands out the lowest slot first (packed toward block fronts, like
        the flat handle)."""
        self._id_pos = {}
        self._free = []
        for s in range(row_ids.shape[0]):
            rid = row_ids[s]
            for slot in np.flatnonzero(rid >= 0):
                self._id_pos[int(rid[slot])] = (s, int(slot))
            self._free.append(
                sorted(np.flatnonzero(rid < 0).tolist(), reverse=True))

    # -------------------------------------------------------------- insert
    def insert(self, rows) -> list[int]:
        """Insert ``rows`` ([n, d] or [d]); returns their external ids.

        Placement (shard + slot per row) is decided host-side from the
        replicated mirrors *before* any device work; the apply is one
        vmapped masked scatter per table.  Appending blocks (all tails
        full) is a shape change — every shard grows together and the next
        search retraces once; otherwise the mutation is shape-stable and
        the cached sharded executables keep serving at zero retraces.
        """
        rows64 = np.asarray(rows, np.float64)
        if rows64.ndim == 1:
            rows64 = rows64[None, :]
        n_new = rows64.shape[0]
        if n_new == 0:
            return []
        eng = self.engine
        index = eng.index
        n_shards, n_pad, d = index.db.shape
        if rows64.shape[1] != d:
            raise ValueError(
                f"inserted rows have dim {rows64.shape[1]}, "
                f"index has dim {d}")
        norms = np.linalg.norm(rows64, axis=1, keepdims=True)
        rows64 = rows64 / np.where(norms == 0.0, 1.0, norms)
        bs = n_pad // index.dp_min.shape[1]
        ids = list(range(self._next_id, self._next_id + n_new))

        # ---- placement: a pure function of the replicated host mirrors
        n_add = 0
        placements = []
        for rid in ids:
            s = rid % n_shards
            if not self._free[s]:
                # least-loaded fallback: most free slots, ties lowest shard
                s2 = max(range(n_shards),
                         key=lambda j: (len(self._free[j]), -j))
                if self._free[s2]:
                    s = s2
                else:
                    # all tails full: append one block to EVERY shard
                    base = n_pad + n_add * bs
                    for fl in self._free:
                        fl.extend(range(base + bs - 1, base - 1, -1))
                    n_add += 1
                    s = rid % n_shards
            placements.append((s, self._free[s].pop()))
        shape_changed = n_add > 0
        if shape_changed:
            index = self._ops.grow(index, n_add=n_add)

        # ---- uniform-width per-shard update operands (replicated)
        per_shard = [[] for _ in range(n_shards)]
        for (s, slot), rid, row in zip(placements, ids, rows64):
            per_shard[s].append((slot, rid, row))
        width = max(len(v) for v in per_shard)
        slots = np.zeros((n_shards, width), np.int32)
        mask = np.zeros((n_shards, width), bool)
        ids_arr = np.full((n_shards, width), -1, np.int32)
        rows_arr = np.zeros((n_shards, width, d), np.float32)
        for s, entries in enumerate(per_shard):
            for j, (slot, rid, row) in enumerate(entries):
                slots[s, j] = slot
                mask[s, j] = True
                ids_arr[s, j] = rid
                rows_arr[s, j] = row
        rep = self._ops.replicate
        mask_r = rep(mask)
        new_index, dp_new = self._ops.insert(
            index, rep(slots), mask_r, rep(rows_arr), rep(ids_arr))

        shard_tree = None
        if not shape_changed and eng._shard_tree is not None:
            shard_tree = self._ops.widen(
                eng._shard_tree, rep((slots // bs).astype(np.int32)),
                dp_new, mask_r)

        for rid, loc in zip(ids, placements):
            self._id_pos[rid] = loc
        self._next_id += n_new
        self.generation += 1
        self._mutations_since_opt += n_new
        eng._apply_mutation(new_index, n_valid=len(self._id_pos),
                            shape_changed=shape_changed,
                            shard_tree=shard_tree)
        self._maybe_reoptimize()
        return ids

    # -------------------------------------------------------------- delete
    def delete(self, ids) -> None:
        """Tombstone-delete rows by external id (semantics of
        :meth:`MutableIndex.delete`, applied to each row's owning shard).
        """
        if isinstance(ids, (int, np.integer)):
            ids = [ids]
        ids = [int(i) for i in ids]
        if not ids:
            return
        bad = [i for i in ids if i not in self._id_pos]
        if bad:
            raise KeyError(
                f"row ids {bad} are not in the live set (never inserted, "
                f"or already deleted)")
        if len(set(ids)) != len(ids):
            raise KeyError(f"duplicate row ids in delete: {ids}")
        eng = self.engine
        n_shards = eng.index.db.shape[0]
        locs = [self._id_pos.pop(i) for i in ids]
        per_shard = [[] for _ in range(n_shards)]
        for s, slot in locs:
            per_shard[s].append(slot)
            self._free[s].append(slot)
        for s in {s for s, _ in locs}:
            self._free[s].sort(reverse=True)
        width = max(len(v) for v in per_shard)
        slots = np.zeros((n_shards, width), np.int32)
        mask = np.zeros((n_shards, width), bool)
        for s, sl in enumerate(per_shard):
            slots[s, :len(sl)] = sl
            mask[s, :len(sl)] = True
        rep = self._ops.replicate
        new_index = self._ops.delete(eng.index, rep(slots), rep(mask))
        self.generation += 1
        self._mutations_since_opt += len(ids)
        eng._apply_mutation(new_index, n_valid=len(self._id_pos),
                            shape_changed=False)
        self._maybe_reoptimize()

    # ---------------------------------------------------------- reoptimize
    def reoptimize(self) -> None:
        """Per-shard repack: drop tombstones, restore angular block
        coherence (build_index's reorder key under each shard's existing
        pivots), recompute every interval from live rows only, and shrink
        the common padded size to fit the fullest shard.  External ids are
        preserved (rows carry them through the permutation); no row moves
        across shards and no pivot is reselected.  A shape change: caches
        drop, next search retraces once.  Works uniformly down to the
        empty live set (one all-padding block per shard)."""
        eng = self.engine
        index = eng.index
        from repro.core.distributed import replicated_row_ids
        self._rows_at_opt = max(1, len(self._id_pos))
        self._mutations_since_opt = 0
        self.generation += 1
        n_shards, n_pad, _ = index.db.shape
        bs = n_pad // index.dp_min.shape[1]
        per_live = np.zeros(n_shards, np.int64)
        for s, _ in self._id_pos.values():
            per_live[s] += 1
        max_live = int(per_live.max()) if self._id_pos else 0
        n_pad_new = max(bs, -(-max_live // bs) * bs)
        new_index = self._ops.repack(index, n_pad_new=n_pad_new)
        self._sync_mirrors(replicated_row_ids(new_index, eng.mesh))
        eng._apply_mutation(new_index, n_valid=len(self._id_pos),
                            shape_changed=True)
