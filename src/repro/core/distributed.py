"""Mesh-sharded exact cosine search: the pod-scale datastore.

This module is the engine room of the SearchEngine's ``"sharded"`` backend
(:mod:`repro.search.backends`): the datastore rows shard across every device
of the mesh (the product of all named axes handed in).  Each device holds
its own :class:`BlockIndex` shard — pivots are *local* to a shard, which
keeps build embarrassingly parallel and, because a shard covers a narrower
slice of the sphere, makes the local Eq. 13 bounds slightly tighter than
global pivots would be.

Search is the shard-local *scan* inner loop (so the engine's τ warm-start
and best-first ordering apply per shard) followed by a tiny global merge:
``all_gather`` of the per-shard (k sims, k global ids) — ``O(devices * k)``
bytes, negligible next to the avoided score matmuls — then ``lax.top_k``.
Exactness is preserved: every shard returns its true local top-k and the
union of local top-k sets contains the global top-k.

With per-shard pivot trees (``SearchEngine(tree_shards=...)``) the local
scan is preceded by the transitive Eq. 13 descent over each shard's own
tree, pruning against a **global** τ assembled from every shard's
warm-start candidates by a second tiny collective (mask-carrying top-k
merge, ``O(devices * k)``) — DESIGN.md §3.6.  The merge argument weakens
from "every shard returns its local top-k" to "every dropped candidate is
provably below the global k-th best", which is still exact.

At 1000+ nodes this is the standard sharded-retrieval pattern (one shard per
chip, single small collective per query batch); the same code runs on any
mesh because only the flattened axis names are referenced.

**Multi-host** (DESIGN.md §3.7): :func:`build_sharded_index_local` is the
process-local variant of the build — each host builds pivots, blocks and
interval caches over only the shard rows it owns and the global stacked
index is assembled with ``jax.make_array_from_process_local_data``
(behind :func:`repro.dist.compat.make_process_local_array`), so no host
ever materializes the full datastore.  Search needs no multi-host
changes at all: the per-shard work and the τ / top-k merges already run
as collectives inside ``shard_map``, which is topology-blind — the same
jitted program serves one process with eight virtual devices and eight
hosts with one chip each.  Exactness is likewise unchanged, because
pivots were *always* shard-local (see §3.7: local pivots only loosen a
shard's bounds relative to global pivots, and a loose bound can only
under-prune, never cut a true neighbor).

**Online mutation** (DESIGN.md §3.10): sharded engines are mutable through
:class:`repro.core.online.ShardedMutableIndex`, obtained transparently via
``SearchEngine.online()``.  The cross-host question — which shard owns a
new row? — is answered by a *deterministic placement protocol*: external
ids come from a replicated monotone counter and map to an owning shard
round-robin by id, falling back to the least-loaded free list when the
preferred shard's tail is full (appending one all-padding block to every
shard when all tails are full, keeping the stacked shapes uniform).
Placement is a pure function of replicated host state (the id → (shard,
slot) map every process mirrors from the replicated ``row_ids``), so all
processes decide identically with **zero extra collectives**; each process
then applies only its own shards' slices through the vmapped masked
scatters behind :func:`make_sharded_mutation`.  Widening (§3.9) holds
shard-locally, and the merges never assumed anything about row placement,
so search stays exact — see §3.10 for the full argument.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.index import BlockIndex, build_index

__all__ = ["build_sharded_index", "build_sharded_index_local",
           "local_shard_rows", "make_sharded_search", "sharded_search_local",
           "place_sharded_index", "make_sharded_mutation",
           "replicated_row_ids"]


def _build_shard_part(shard, n_valid: int, row_offset: int, *,
                      n_pivots: int, block_size: int,
                      pivot_method: str) -> BlockIndex:
    """One shard's :class:`BlockIndex` with GLOBAL row ids baked in.

    The one per-shard build both :func:`build_sharded_index` and
    :func:`build_sharded_index_local` call — keeping it shared is what
    makes the process-local build bit-identical to the single-controller
    one (same rows in ⇒ same pivots, reorder, intervals out).
    """
    idx = build_index(
        jnp.asarray(shard), n_pivots=n_pivots, block_size=block_size,
        pivot_method=pivot_method if n_valid > n_pivots else "random",
    )
    # mark padding rows (zero vectors) invalid even when build_index's own
    # padding did not cover them (row_ids tracks the pre-reorder position),
    # and bake GLOBAL row ids in, so the merge needs no rank arithmetic
    # (robust to any device->shard mapping).
    valid = idx.valid & (idx.row_ids >= 0) & (idx.row_ids < n_valid)
    gids = jnp.where(valid, idx.row_ids + row_offset, -1).astype(jnp.int32)
    return idx._replace(valid=valid, row_ids=gids)


def build_sharded_index(
    db: np.ndarray,
    n_shards: int,
    *,
    n_pivots: int = 16,
    block_size: int = 128,
    pivot_method: str = "maxmin",
) -> BlockIndex:
    """Split ``db`` row-wise into ``n_shards`` and build one index per shard.

    Returns a :class:`BlockIndex` whose arrays carry a leading shard axis
    ``[S, ...]`` — place it with ``NamedSharding(mesh, P(axis))`` so that each
    device materializes only its own shard.  Rows pad to equal shard sizes.
    """
    db = np.asarray(db, np.float32)
    n = db.shape[0]
    per = -(-n // n_shards)
    pad = per * n_shards - n
    if pad:
        db = np.concatenate([db, np.zeros((pad, db.shape[1]), np.float32)], 0)
    parts = []
    for s in range(n_shards):
        parts.append(_build_shard_part(
            db[s * per : (s + 1) * per],
            n_valid=min(per, max(0, n - s * per)), row_offset=s * per,
            n_pivots=n_pivots, block_size=block_size,
            pivot_method=pivot_method))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
    return stacked


def _flat_axes(mesh: Mesh, axis_names) -> tuple[str, ...]:
    axis = tuple(axis_names or mesh.axis_names)
    if jax.process_count() > 1 and set(axis) != set(mesh.axis_names):
        raise NotImplementedError(
            "multi-host sharded build supports sharding over ALL mesh axes "
            f"only (got axis_names={axis!r} on a mesh with axes "
            f"{mesh.axis_names!r}); replicated shard axes would need "
            "identical cross-host replicas")
    return axis


def local_shard_rows(n_rows: int, mesh: Mesh, axis_names=None):
    """Which global datastore rows THIS process's shards cover.

    The sharded datastore places one shard per device of the flattened
    mesh axes; ownership is read off the placement sharding's own index
    map (``NamedSharding(mesh, P(axis)).devices_indices_map``), so the
    shard-id ↔ device assignment is by construction the one
    ``place_sharded_index`` / ``make_array_from_process_local_data`` use
    — including permuted ``axis_names`` orders, which flatten differently
    from ``mesh.devices``.  Returns ``(per, owned)`` where ``per`` is the
    global rows-per-shard (``ceil(n_rows / n_shards)``) and ``owned`` is
    this process's shards as ``[(shard_id, row_start, row_stop), ...]``
    in ascending shard order — the order a process-local datastore slab
    must be concatenated in for :func:`build_sharded_index_local`.
    ``row_stop`` is clamped to ``n_rows`` (the trailing shard may be
    short; its tail pads with invalid rows at build time).
    """
    axis = _flat_axes(mesh, axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis]))
    imap = NamedSharding(mesh, P(axis)).devices_indices_map((n_shards,))
    pid = jax.process_index()
    owned_ids = sorted({(idx[0].start or 0) for d, idx in imap.items()
                        if d.process_index == pid})
    per = -(-n_rows // n_shards)
    owned = [(s, min(s * per, n_rows), min((s + 1) * per, n_rows))
             for s in owned_ids]
    return per, owned


def build_sharded_index_local(
    db_local: np.ndarray,
    mesh: Mesh,
    *,
    global_rows: int,
    axis_names=None,
    n_pivots: int = 16,
    block_size: int = 128,
    pivot_method: str = "maxmin",
) -> BlockIndex:
    """Process-local sharded build: assemble the global index from each
    host's own rows (DESIGN.md §3.7).

    ``db_local`` holds ONLY the rows this process's shards cover — the
    concatenation, in ascending shard order, of the ``local_shard_rows``
    ranges (for the usual contiguous ownership that is one slice of the
    logical datastore).  Every per-shard index (pivots, reorder, interval
    caches) is built host-side from those rows alone, then the stacked
    global :class:`BlockIndex` is assembled leaf-by-leaf with
    ``make_array_from_process_local_data`` — each device materializes
    exactly its own shard and no host ever holds the full datastore.

    ``global_rows`` is the TOTAL logical row count across all hosts
    (metadata every launcher knows; it fixes the rows-per-shard split and
    the global row-id offsets).  The result is placed like
    :func:`place_sharded_index` would place it — ``P(axis_names)`` over
    the flattened mesh axes — and is bit-identical, shard for shard, to
    ``build_sharded_index(full_db, n_shards)`` on the same rows: both
    call the same per-shard builder.  Search then works unchanged (the
    merges are collectives inside ``shard_map``); exactness never
    depended on cross-shard pivot knowledge in the first place.
    """
    db_local = np.asarray(db_local, np.float32)
    axis = _flat_axes(mesh, axis_names)
    per, owned = local_shard_rows(global_rows, mesh, axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axis]))
    expected = sum(stop - start for _, start, stop in owned)
    if db_local.shape[0] != expected:
        raise ValueError(
            f"db_local has {db_local.shape[0]} rows but this process's "
            f"shards {[s for s, _, _ in owned]} cover {expected} of the "
            f"{global_rows} global rows ({per} per shard across {n_shards} "
            f"shards); slice the datastore with local_shard_rows()")
    parts, ofs = [], 0
    for s, start, stop in owned:
        cnt = stop - start
        shard = db_local[ofs:ofs + cnt]
        ofs += cnt
        if cnt < per:  # trailing short shard: pad with invalid zero rows
            shard = np.concatenate(
                [shard, np.zeros((per - cnt, db_local.shape[1]), np.float32)])
        parts.append(_build_shard_part(
            shard, n_valid=cnt, row_offset=s * per, n_pivots=n_pivots,
            block_size=block_size, pivot_method=pivot_method))
    from repro.dist.compat import make_process_local_array
    local = jax.tree.map(
        lambda *xs: np.stack([np.asarray(x) for x in xs]), *parts)
    sh = NamedSharding(mesh, P(axis))
    return jax.tree.map(
        lambda leaf: make_process_local_array(
            sh, leaf, (n_shards,) + leaf.shape[1:]), local)


def sharded_search_local(index: BlockIndex, queries: Array, k: int, axis_names,
                         *, prune: bool = True,
                         warm_start: bool = False, best_first: bool = False,
                         warm_start_blocks: int | None = None,
                         element_stats: bool = False,
                         with_stats: bool = False,
                         tree=None, margin: float = 4e-7,
                         n_pivots: int = 0):
    """Body that runs inside ``shard_map``: local scan + global merge.

    ``index`` arrives with the leading shard axis of size 1 (this device's
    shard); ``queries`` are replicated.  ``warm_start`` / ``best_first`` /
    ``warm_start_blocks`` / ``element_stats`` are the engine policies,
    applied to each shard's local scan (the τ prescan seeds from each
    shard's own best-bound blocks — DESIGN.md §3.4).

    With ``tree`` (a :class:`~repro.search.tree.ShardTreeArrays`, leading
    shard axis of size 1) each shard instead runs the transitive Eq. 13
    descent over its *local* pivot tree before the leaf scan — DESIGN.md
    §3.6.  The τ the descent prunes against is **global**: every shard's
    beam warm-start candidates are merged with the mask-carrying top-k
    all-gather and the k-th best of the union is broadcast back, so each
    shard's pruning threshold is at least the flat path's local seed
    (per-shard pruning is a superset of the flat per-shard pruning) while
    remaining a true lower bound on the global k-th best (cut subtrees
    provably hold no global top-k member, so the merge stays exact).
    Everything stays statically shaped — the surviving leaves are a
    boolean mask into the local scan, not a compaction — which is what
    ``shard_map`` tracing requires.
    """
    from repro.dist.collectives import global_tau_merge, topk_allgather_merge
    from repro.search.backends import map_row_ids, prep_queries, scan_search
    local = jax.tree.map(lambda x: x[0], index)
    qn, qp = prep_queries(local, queries)
    m = qn.shape[0]
    if tree is None:
        sims, pos, blk_pruned, elem_pruned = scan_search(
            local, qn, qp, k, prune=prune, margin=margin,
            warm_start=warm_start, best_first=best_first,
            warm_start_blocks=warm_start_blocks, element_stats=element_stats,
            n_pivots=n_pivots)
        tree_pruned = evals = None
    else:
        # the descent is pure masking work with prune off — the backend
        # only hands a tree in when pruning is on
        assert prune, "tree descent requires prune=True"
        from repro.search.tree import TreeIndex, _seed_and_descend
        ltree = TreeIndex(local, tree.node_lo[0], tree.node_hi[0],
                          tree.node_valid[0])
        # the one exactness-critical seed -> descend -> flat-reseed
        # sequence, shared with the single-device tree backend; the merge
        # hook turns each shard's beam candidates into ONE global τ per
        # query (mask-carrying, so shards holding < k candidates still
        # contribute theirs) — §3.6
        tau0, leaf_alive, leaf_ub, evals = _seed_and_descend(
            ltree, qn, qp, k, warm_start=warm_start,
            warm_start_blocks=warm_start_blocks, margin=margin,
            tau_merge=lambda s, v: global_tau_merge(s, v, k, axis_names))
        if n_pivots > 0:
            # eq13_multi over the LOCAL shard tables (pivots — and so the
            # joint basis — were always shard-local); the leaf scan below
            # consumes the tightened bound matrix unchanged
            from repro.core.index import multipivot_block_cap
            leaf_ub = jnp.minimum(
                leaf_ub, multipivot_block_cap(local, qn, n_pivots=n_pivots))
        sims, pos, blk_pruned, elem_pruned = scan_search(
            local, qn, qp, k, margin=margin, warm_start=False,
            best_first=best_first, element_stats=element_stats,
            tau0=tau0, ub_all=leaf_ub, leaf_mask=leaf_alive)
        tree_pruned = (~leaf_alive).sum().astype(jnp.float32)
    # build_sharded_index bakes GLOBAL ids into row_ids — no rank arithmetic
    gids = map_row_ids(local.row_ids, pos)
    # tiny collective: O(devices * k) candidates
    merged = topk_allgather_merge(sims, gids, k, axis_names)
    if not with_stats:
        return merged
    # psum-weighted aggregates: sums of per-shard counts over sums of
    # per-shard denominators, so unevenly-filled shards weight correctly
    # (the bug class tests/test_sharded_tree.py pins down)
    nb_sum = jax.lax.psum(jnp.float32(local.n_blocks), axis_names)
    frac = jax.lax.psum(blk_pruned, axis_names) / (m * nb_sum)
    n_valid = local.valid.sum().astype(jnp.float32)
    efrac = (jax.lax.psum(elem_pruned, axis_names)
             / jnp.maximum(1.0, m * jax.lax.psum(n_valid, axis_names)))
    if tree is None:
        return merged + (frac, efrac)
    tfrac = jax.lax.psum(tree_pruned, axis_names) / (m * nb_sum)
    nodes = jax.lax.psum(ltree.node_valid.sum().astype(jnp.float32),
                         axis_names)
    evfrac = jax.lax.psum(evals, axis_names) / jnp.maximum(1.0, m * nodes)
    return merged + (frac, efrac, tfrac, evfrac)


def make_sharded_search(mesh: Mesh, axis_names: tuple[str, ...] | None = None,
                        *, prune: bool = True,
                        warm_start: bool = False, best_first: bool = False,
                        warm_start_blocks: int | None = None,
                        element_stats: bool = False,
                        with_stats: bool = False,
                        margin: float = 4e-7,
                        n_pivots: int = 0,
                        trace_hook=None):
    """Build a jitted ``(index, queries, k[, tree]) -> (sims, gids)`` closure.

    ``trace_hook`` (optional zero-arg callable) is invoked inside the
    traced body, i.e. exactly once per trace+compile and never on cached
    dispatches — the engine passes its retrace counter so the sharded
    path's ``SearchStats.retraces`` is as observable as the flat ones.

    ``axis_names`` defaults to *all* mesh axes — the datastore shards over
    every chip.  Results are fully replicated.  With ``with_stats`` the
    closure additionally returns the psum-weighted block-prune fraction
    and the global element-prune fraction (0 unless ``element_stats``).

    Pass ``tree`` (a shard-stacked
    :class:`~repro.search.tree.ShardTreeArrays`, placed like the index) to
    run the per-shard transitive Eq. 13 descent with the broadcast global
    τ before each shard's leaf scan (DESIGN.md §3.6); with ``with_stats``
    the closure then also returns the psum-weighted ``tree_prune_frac``
    and ``tree_node_eval_frac``.
    """
    axis_names = tuple(axis_names or mesh.axis_names)

    from repro.dist.compat import shard_map

    @functools.partial(jax.jit, static_argnames=("k",))
    def run(index: BlockIndex, queries: Array, k: int, tree=None):
        if trace_hook is not None:
            trace_hook()
        body = functools.partial(
            sharded_search_local, k=k, axis_names=axis_names, prune=prune,
            warm_start=warm_start, best_first=best_first,
            warm_start_blocks=warm_start_blocks,
            element_stats=element_stats, with_stats=with_stats,
            margin=margin, n_pivots=n_pivots)
        n_stats = (6 if tree is not None else 4) if with_stats else 2
        idx_specs = jax.tree.map(lambda _: P(axis_names), index)
        if tree is None:
            fn = shard_map(
                body, mesh=mesh, in_specs=(idx_specs, P()),
                out_specs=(P(),) * n_stats, check_vma=False)
            return fn(index, queries)
        fn = shard_map(
            lambda idx, q, tr: body(idx, q, tree=tr),
            mesh=mesh,
            in_specs=(idx_specs, P(), jax.tree.map(lambda _: P(axis_names),
                                                   tree)),
            out_specs=(P(),) * n_stats, check_vma=False)
        return fn(index, queries, tree)

    return run


def place_sharded_index(index: BlockIndex, mesh: Mesh, axis_names=None) -> BlockIndex:
    """Device-put a stacked index with the shard axis over the mesh axes."""
    axis_names = tuple(axis_names or mesh.axis_names)
    sh = NamedSharding(mesh, P(axis_names))
    return jax.tree.map(lambda x: jax.device_put(x, sh), index)


def replicated_row_ids(index: BlockIndex, mesh: Mesh) -> np.ndarray:
    """Host copy of a stacked index's ``row_ids`` — ``[S, n_pad]`` int32.

    The one replication the sharded online handle performs, at handle init
    and after each :meth:`~repro.core.online.ShardedMutableIndex.reoptimize`
    (both rebuild events, never the per-mutation hot path): multi-host
    ``row_ids`` are not addressable outside jit, so an identity jit with
    replicated ``out_shardings`` all-gathers them and every process reads
    the same full copy off its first addressable shard.  From this mirror
    each process derives the id → (shard, slot) map and the per-shard free
    lists — the *replicated host state* the placement protocol is a pure
    function of (DESIGN.md §3.10).
    """
    rid = index.row_ids
    if isinstance(rid, jax.Array) and not rid.is_fully_addressable:
        rep = jax.jit(lambda x: x,
                      out_shardings=NamedSharding(mesh, P()))(rid)
        return np.asarray(rep.addressable_shards[0].data)
    return np.asarray(rid)


class ShardedMutationOps:
    """Jitted device-apply closures for one sharded engine's mutations.

    Built once per online handle by :func:`make_sharded_mutation`.  Every
    closure takes the stacked index (sharded ``P(axis)`` over the mesh)
    plus small *replicated* per-shard update operands padded to a uniform
    width R, and applies each shard's slice with vmapped masked scatters —
    masked entries index the out-of-range sentinel and are dropped, so a
    shard receiving fewer (or zero) rows this call is untouched.  All
    outputs keep the index placement (``out_shardings``), so under GSPMD
    each device scatters only into its local shard and the apply itself
    needs no communication.

    ``insert`` computes the new rows' pivot projections **on device, per
    shard** (``rows @ pivots_s.T`` — multi-host processes cannot read other
    shards' pivots host-side); the fp32 joint-table rows it writes differ
    from the flat path's fp64-then-cast ones by ~1e-7, absorbed by
    ``JOINT_SLACK`` like the stored-basis upcast error already is.
    """

    def __init__(self, mesh: Mesh, axis_names=None):
        axis = _flat_axes(mesh, axis_names)
        self.mesh = mesh
        self.axis = axis
        self.sharding = NamedSharding(mesh, P(axis))
        sh = self.sharding

        def _insert(index, slots, mask, rows, ids):
            def one(idx, sl, mk, rw, di):
                n_pad = idx.db.shape[0]
                nb = idx.dp_min.shape[0]
                bs = n_pad // nb
                dp_new = rw @ idx.pivots.T                   # [R, P]
                sl_s = jnp.where(mk, sl, n_pad)              # drop padding
                blk = jnp.where(mk, sl // bs, nb)
                new = idx._replace(
                    db=idx.db.at[sl_s].set(rw, mode="drop"),
                    dp=idx.dp.at[sl_s].set(dp_new, mode="drop"),
                    valid=idx.valid.at[sl_s].set(True, mode="drop"),
                    row_ids=idx.row_ids.at[sl_s].set(di, mode="drop"),
                    dp_min=idx.dp_min.at[blk].min(dp_new, mode="drop"),
                    dp_max=idx.dp_max.at[blk].max(dp_new, mode="drop"),
                )
                if idx.ortho is not None:
                    beta = rw @ idx.ortho.T
                    bnsq = jnp.cumsum(beta * beta, axis=1)
                    new = new._replace(
                        beta=idx.beta.at[sl_s].set(beta, mode="drop"),
                        beta_nsq=idx.beta_nsq.at[sl_s].set(bnsq,
                                                           mode="drop"))
                return new, dp_new

            return jax.vmap(one)(index, slots, mask, rows, ids)

        def _delete(index, slots, mask):
            def one(idx, sl, mk):
                sl_s = jnp.where(mk, sl, idx.valid.shape[0])
                return idx._replace(
                    valid=idx.valid.at[sl_s].set(False, mode="drop"),
                    row_ids=idx.row_ids.at[sl_s].set(-1, mode="drop"))

            return jax.vmap(one)(index, slots, mask)

        def _grow(index, *, n_add):
            s = index.db.shape[0]
            d = index.db.shape[2]
            p = index.dp.shape[2]
            bs = index.db.shape[1] // index.dp_min.shape[1]
            nr = n_add * bs
            zdp = jnp.zeros((s, nr, p), index.dp.dtype)
            new = index._replace(
                db=jnp.concatenate(
                    [index.db, jnp.zeros((s, nr, d), index.db.dtype)], 1),
                dp=jnp.concatenate([index.dp, zdp], 1),
                valid=jnp.concatenate(
                    [index.valid, jnp.zeros((s, nr), index.valid.dtype)], 1),
                row_ids=jnp.concatenate(
                    [index.row_ids, jnp.full((s, nr), -1, jnp.int32)], 1),
                # empty-interval sentinel: the first insert records its
                # exact min/max (same convention as the flat append path)
                dp_min=jnp.concatenate(
                    [index.dp_min,
                     jnp.full((s, n_add, p), jnp.inf, index.dp_min.dtype)],
                    1),
                dp_max=jnp.concatenate(
                    [index.dp_max,
                     jnp.full((s, n_add, p), -jnp.inf, index.dp_max.dtype)],
                    1),
            )
            if index.beta is not None:
                new = new._replace(
                    beta=jnp.concatenate([index.beta, zdp], 1),
                    beta_nsq=jnp.concatenate([index.beta_nsq, zdp], 1))
            return new

        def _repack(index, *, n_pad_new):
            def one(idx):
                p = idx.dp.shape[1]
                bs = idx.db.shape[0] // idx.dp_min.shape[0]
                # build_index's reorder key: (nearest pivot asc, similarity
                # to it desc), tombstones and padding grouped last
                nearest = jnp.argmax(idx.dp, axis=1).astype(jnp.int32)
                near_sim = jnp.max(idx.dp, axis=1)
                group = jnp.where(idx.valid, nearest, p)
                perm = jnp.lexsort((-near_sim, group))
                db = idx.db[perm][:n_pad_new]
                dp = idx.dp[perm][:n_pad_new]
                valid = idx.valid[perm][:n_pad_new]
                rid = jnp.where(valid, idx.row_ids[perm][:n_pad_new], -1)
                nb2 = n_pad_new // bs
                dmin = jnp.where(valid[:, None], dp,
                                 jnp.inf).reshape(nb2, bs, p).min(axis=1)
                dmax = jnp.where(valid[:, None], dp,
                                 -jnp.inf).reshape(nb2, bs, p).max(axis=1)
                new = idx._replace(db=db, dp=dp, valid=valid, row_ids=rid,
                                   dp_min=dmin, dp_max=dmax)
                if idx.beta is not None:
                    new = new._replace(
                        beta=idx.beta[perm][:n_pad_new],
                        beta_nsq=idx.beta_nsq[perm][:n_pad_new])
                return new

            return jax.vmap(one)(index)

        def _widen(tree, blocks, dp_rows, mask):
            from repro.search.tree import widen_shard_trees
            return widen_shard_trees(tree, blocks, dp_rows, mask)

        self.insert = jax.jit(_insert, out_shardings=sh)
        self.delete = jax.jit(_delete, out_shardings=sh)
        self.grow = jax.jit(_grow, static_argnames="n_add", out_shardings=sh)
        self.repack = jax.jit(_repack, static_argnames="n_pad_new",
                              out_shardings=sh)
        self.widen = jax.jit(_widen, out_shardings=sh)

    def replicate(self, x) -> Array:
        """Small host update operand -> replicated global device array."""
        from repro.dist.compat import replicate_to_mesh
        return replicate_to_mesh(np.asarray(x), self.mesh)


def make_sharded_mutation(mesh: Mesh, axis_names=None) -> ShardedMutationOps:
    """Build the jitted sharded-mutation closures for ``mesh``.

    Called once per :class:`~repro.core.online.ShardedMutableIndex`; the
    returned object's jit caches persist for the handle's lifetime, so
    shape-stable mutations dispatch without retracing (the index is an
    argument, exactly like the search closures).  Per-shard *repack*
    (``reoptimize``) deliberately moves no row across shards and keeps each
    shard's existing pivots: tightening intervals, dropping tombstones and
    re-coherent block packing are all shard-local, which is what keeps the
    rebuild collective-free (DESIGN.md §3.10).
    """
    return ShardedMutationOps(mesh, axis_names)
