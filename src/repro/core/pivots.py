"""Pivot (reference point) selection for LAESA-style bound pruning.

The quality of the Eq. 13 pruning bound depends on how well the pivots
"cover" the dataset in angle space: a candidate is pruned when some pivot z
has ``ub_mult(sim(q,z), sim(y,z)) < tau``, which is tightest when z is nearly
collinear with q or y.  We use greedy max-min (farthest-first / k-center)
selection in arc distance, the standard choice for metric indexes, plus a
cheap random fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array


def normalize(x: Array, eps: float = 1e-12) -> Array:
    """L2-normalize along the last axis (safe for zero rows)."""
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(n, eps)


def select_pivots_maxmin(db: Array, n_pivots: int, *, first: int = 0) -> Array:
    """Greedy farthest-first pivot selection (returns pivot *indices*).

    Iteratively picks the point whose maximum similarity to the already
    selected pivots is smallest (i.e. the angularly farthest point).  Runs in
    O(n_pivots * n * d) — jit-friendly via ``lax.fori_loop``.

    Args:
      db: [n, d] database (need not be normalized; it is normalized here).
      n_pivots: number of pivots to select (>= 1).
      first: index of the initial pivot (deterministic by default).
    """
    dbn = normalize(db.astype(jnp.float32))
    n = dbn.shape[0]

    def body(i, state):
        idx, max_sim = state
        # similarity of every point to the i-1'th chosen pivot
        prev = dbn[idx[i - 1]]
        sims = dbn @ prev
        max_sim = jnp.maximum(max_sim, sims)
        # next pivot: the point least similar to all chosen so far
        nxt = jnp.argmin(max_sim)
        idx = idx.at[i].set(nxt)
        return idx, max_sim

    idx0 = jnp.zeros((n_pivots,), jnp.int32).at[0].set(first)
    max_sim0 = jnp.full((n,), -jnp.inf, jnp.float32)
    idx, _ = jax.lax.fori_loop(1, n_pivots, body, (idx0, max_sim0))
    return idx


def select_pivots_random(n: int, n_pivots: int, seed: int = 0) -> Array:
    """Uniform random pivot indices (cheap baseline).

    ``n_pivots`` is clamped to ``n``: asking for more pivots than points is
    a degenerate-but-reachable configuration (tiny shards route here, see
    ``repro.core.distributed``), and ``choice(replace=False)`` would raise.
    """
    rng = np.random.default_rng(seed)
    n_pivots = max(1, min(n_pivots, n))
    return jnp.asarray(rng.choice(n, size=n_pivots, replace=False).astype(np.int32))


def suggest_bound_pivots(n: int, d: int) -> int:
    """Pivot-table depth for the joint ``eq13_multi`` bound (see
    :mod:`repro.core.bounds`).

    ``d`` pivots span the whole space — the joint projection bound then
    *equals* the exact score (it prunes perfectly but costs a full matmul to
    evaluate), while shallow tables lose all power on uniform high-d data
    (the per-pivot residuals stay near 1).  ``7d/8`` keeps a usable
    orthogonal remainder and is where the uniform-regime block pruning
    plateaus on the pruning bench; clamped to ``n - 1`` so tiny corpora
    stay non-degenerate.
    """
    return max(1, min(7 * d // 8, max(1, n - 1)))


def orthonormal_pivot_basis(pivots, jitter: float = 1e-6) -> np.ndarray:
    """Orthonormalized pivot basis ``U = R^{-1} Z`` for the joint bound.

    ``Z`` [P, d] are the (unit) pivot rows, ``G = Z Z^T`` their Gram, and
    ``R`` the lower Cholesky factor of ``G + jitter*I``.  The rows of ``U``
    are the first ``P`` vectors of a Gram–Schmidt basis of the *lifted*
    pivots ``z~_i = (z_i, sqrt(jitter)*e_i)`` (whose Gram is exactly
    ``G + jitter*I``), so for any unit ``x`` the coordinate vector
    ``alpha = U @ x`` satisfies ``|alpha| <= 1`` and the joint upper bound
    of :func:`repro.core.bounds.ub_joint` is valid — including for
    duplicate or linearly dependent pivots, where the jitter keeps the
    factorization defined (DESIGN.md §3.8).

    Because ``R`` is lower triangular and the maxmin selection is nested
    (greedy), the first ``k`` rows of ``U`` are exactly the basis that a
    ``k``-pivot table would have built: one full-width table serves every
    prefix ``n_pivots <= P``.

    Host-side float64 numpy (build-time only); escalates the jitter ×10
    until the factorization succeeds.
    """
    z = np.asarray(pivots, np.float64)
    p = z.shape[0]
    gram = z @ z.T
    eps = float(jitter)
    for _ in range(24):
        try:
            chol = np.linalg.cholesky(gram + eps * np.eye(p))
            break
        except np.linalg.LinAlgError:
            eps *= 10.0
    else:  # pragma: no cover - float64 PSD + jitter cannot get here
        raise np.linalg.LinAlgError("pivot Gram not factorizable")
    from scipy.linalg import solve_triangular

    return solve_triangular(chol, z, lower=True)
