"""Pivot (reference point) selection for LAESA-style bound pruning.

The quality of the Eq. 13 pruning bound depends on how well the pivots
"cover" the dataset in angle space: a candidate is pruned when some pivot z
has ``ub_mult(sim(q,z), sim(y,z)) < tau``, which is tightest when z is nearly
collinear with q or y.  We use greedy max-min (farthest-first / k-center)
selection in arc distance, the standard choice for metric indexes, plus a
cheap random fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array


def normalize(x: Array, eps: float = 1e-12) -> Array:
    """L2-normalize along the last axis (safe for zero rows)."""
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(n, eps)


def select_pivots_maxmin(db: Array, n_pivots: int, *, first: int = 0) -> Array:
    """Greedy farthest-first pivot selection (returns pivot *indices*).

    Iteratively picks the point whose maximum similarity to the already
    selected pivots is smallest (i.e. the angularly farthest point).  Runs in
    O(n_pivots * n * d) — jit-friendly via ``lax.fori_loop``.

    Args:
      db: [n, d] database (need not be normalized; it is normalized here).
      n_pivots: number of pivots to select (>= 1).
      first: index of the initial pivot (deterministic by default).
    """
    dbn = normalize(db.astype(jnp.float32))
    n = dbn.shape[0]

    def body(i, state):
        idx, max_sim = state
        # similarity of every point to the i-1'th chosen pivot
        prev = dbn[idx[i - 1]]
        sims = dbn @ prev
        max_sim = jnp.maximum(max_sim, sims)
        # next pivot: the point least similar to all chosen so far
        nxt = jnp.argmin(max_sim)
        idx = idx.at[i].set(nxt)
        return idx, max_sim

    idx0 = jnp.zeros((n_pivots,), jnp.int32).at[0].set(first)
    max_sim0 = jnp.full((n,), -jnp.inf, jnp.float32)
    idx, _ = jax.lax.fori_loop(1, n_pivots, body, (idx0, max_sim0))
    return idx


def select_pivots_random(n: int, n_pivots: int, seed: int = 0) -> Array:
    """Uniform random pivot indices (cheap baseline)."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.choice(n, size=n_pivots, replace=False).astype(np.int32))
