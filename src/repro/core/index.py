"""Block-pruned exact cosine kNN — the TPU-native adaptation of the paper.

The metric indexes the paper targets (VP-tree, LAESA, M-tree, ...) prune one
candidate at a time while walking pointer-based trees.  On TPU we keep the
*insight* — the Eq. 13 upper bound over cached pivot similarities proves that
a candidate cannot enter the top-k — but apply it at **block granularity** so
the surviving work stays dense and MXU-shaped (see DESIGN.md §2):

  build:   normalize db, pick P pivots, cache ``dp = db @ pivots.T`` and the
           per-block per-pivot interval ``[dp_min, dp_max]``.
  search:  stream blocks with ``lax.scan``; per (query, block) evaluate the
           interval upper bound; blocks below the running k-th-best τ are
           pruned.  Survivors get the exact ``q @ block.T`` matmul and a
           top-k merge.

Exactness: Eq. 13 is a true upper bound, and the interval maximum over a
block dominates every member's bound, so a pruned block provably contains no
true neighbor.  A ``margin`` (few ulps) guards fp32 rounding; the property
tests check bit-exact agreement of the result *set* with the fp64 oracle.

In this pure-JAX module the pruned matmul is still *computed* and masked
(XLA has no data-dependent skip) — the pruning statistics report what a real
TPU run skips; :mod:`repro.kernels.cosine_topk` is the Pallas kernel that
actually skips the work via ``@pl.when``.

The search entry points here are deprecated shims: the inner loops now live
behind :class:`repro.search.SearchEngine` (one backend-dispatched API with
τ warm-start and best-first block ordering); this module keeps the index
*structure* (build, bounds, reorder).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.bounds import joint_row_upper_bound, ub_mult
from repro.core.pivots import (normalize, orthonormal_pivot_basis,
                               select_pivots_maxmin, select_pivots_random)

__all__ = ["BlockIndex", "build_index", "search", "search_brute",
           "interval_upper_bound", "block_upper_bound", "reorder_perm",
           "multipivot_block_cap"]


class BlockIndex(NamedTuple):
    """Immutable search structure (a pytree of arrays; shapes are static).

    ``db`` is padded to a multiple of the block size; ``valid`` masks padding.
    ``dp_min/dp_max`` are the per-block pivot-similarity intervals
    ``[n_blocks, P]``; ``block_size = db.shape[0] // dp_min.shape[0]``.
    """

    db: Array        # [n_pad, d]  normalized, padded database
    dp: Array        # [n_pad, P]  database-to-pivot similarities
    pivots: Array    # [P, d]      normalized pivot vectors
    dp_min: Array    # [n_blocks, P]
    dp_max: Array    # [n_blocks, P]
    valid: Array     # [n_pad]     bool, False on padding rows
    row_ids: Array   # [n_pad]     original row id of each (possibly reordered) row
    # Joint multi-pivot bound tables (None on indexes built before PR 7; every
    # field defaults so old pytree shapes keep unflattening).  ``ortho`` is the
    # orthonormalized pivot basis U = R^-1 Z; beta = db @ U.T; beta_nsq the
    # cumulative squared prefix norms, so one table serves every n_pivots <= P.
    ortho: Array | None = None     # [P, d]
    beta: Array | None = None      # [n_pad, P]
    beta_nsq: Array | None = None  # [n_pad, P]  cumsum(beta**2, axis=1)

    @property
    def n_blocks(self) -> int:
        return self.dp_min.shape[0]

    @property
    def block_size(self) -> int:
        return self.db.shape[0] // self.n_blocks

    @property
    def n_pivots(self) -> int:
        return self.pivots.shape[0]

    @property
    def bound_table_width(self) -> int:
        """Max usable ``n_pivots`` for the joint bound (0 = no table)."""
        return 0 if self.ortho is None else self.ortho.shape[-2]


def build_index(
    db: Array,
    *,
    n_pivots: int = 16,
    block_size: int = 128,
    pivot_method: str = "maxmin",
    reorder: bool = True,
    seed: int = 0,
) -> BlockIndex:
    """Build the block index.  ``block_size`` should be a multiple of 128 on
    real TPU (MXU alignment); any value works functionally.

    ``reorder`` (beyond-paper optimization): permute rows so that each block
    is angularly coherent — rows group by their nearest pivot, descending
    similarity within the group.  Tight per-block pivot intervals are what
    turn the paper's per-point bound into an effective per-*block* bound;
    with natural (shuffled) order the intervals span nearly [-1, 1] and no
    block can ever be pruned.  Search results are returned in original ids
    via ``row_ids``.
    """
    dbn = normalize(jnp.asarray(db, jnp.float32))
    n, d = dbn.shape
    # More pivots than points is degenerate-but-reachable (tiny corpora /
    # shards): clamp so selection and the joint-bound tables stay defined.
    n_pivots = max(1, min(int(n_pivots), n))
    n_pad = -(-n // block_size) * block_size
    pad = n_pad - n
    dbn = jnp.pad(dbn, ((0, pad), (0, 0)))
    valid = jnp.arange(n_pad) < n
    row_ids = jnp.where(valid, jnp.arange(n_pad), -1).astype(jnp.int32)

    if pivot_method == "maxmin":
        piv_idx = select_pivots_maxmin(dbn[:n], n_pivots)
    elif pivot_method == "random":
        piv_idx = select_pivots_random(n, n_pivots, seed)
    else:
        raise ValueError(f"unknown pivot_method {pivot_method!r}")
    pivots = dbn[piv_idx]                      # [P, d] (already unit norm)

    dp = dbn @ pivots.T                        # [n_pad, P]

    if reorder:
        perm = reorder_perm(dp, valid, n_pivots)
        dbn, dp = dbn[perm], dp[perm]
        valid, row_ids = valid[perm], row_ids[perm]
    # Padding rows are zero vectors => dp = 0; exclude them from the block
    # intervals so they can't loosen the bound.
    dp_for_min = jnp.where(valid[:, None], dp, jnp.inf)
    dp_for_max = jnp.where(valid[:, None], dp, -jnp.inf)
    nb = n_pad // block_size
    dp_min = dp_for_min.reshape(nb, block_size, -1).min(axis=1)
    dp_max = dp_for_max.reshape(nb, block_size, -1).max(axis=1)
    # A fully-padded block keeps the +inf/-inf identity of the masked
    # reduce: the *empty-interval sentinel*.  Every bound path maps an
    # inverted interval (lo > hi) to a -inf upper bound, so empty blocks
    # prune unconditionally, and — critically for the online path — an
    # insert's scatter-min/max against the sentinel records the new row's
    # EXACT interval instead of anchoring it at a neutral value.

    # Joint multi-pivot bound tables (float64 at build, float32 stored).
    # Computed on the *reordered* rows so beta[i] matches db[i]; maxmin
    # selection is nested, so prefix slices of these tables are exactly the
    # tables a shallower index would have built.
    import numpy as np
    u64 = orthonormal_pivot_basis(pivots)                   # [P, d] f64
    beta64 = np.asarray(dbn, np.float64) @ u64.T            # [n_pad, P]
    beta_nsq64 = np.cumsum(beta64 * beta64, axis=1)
    ortho = jnp.asarray(u64, jnp.float32)
    beta = jnp.asarray(beta64, jnp.float32)
    beta_nsq = jnp.asarray(beta_nsq64, jnp.float32)
    return BlockIndex(dbn, dp, pivots, dp_min, dp_max, valid, row_ids,
                      ortho, beta, beta_nsq)


def reorder_perm(dp: Array, valid: Array, n_pivots: int) -> Array:
    """Row permutation making blocks angularly coherent.

    Sorts by (nearest pivot asc, similarity to it desc), padding last —
    lexicographically, with the integer group key kept integer.  The old
    float key ``nearest * 4.0 - near_sim`` packed both into one fp32: at
    ``n_pivots = 64`` the key magnitude (~256) costs 8 bits of the
    similarity's mantissa, so within-group sims closer than ~3e-5 collapsed
    and the within-group descending order broke (regression-tested in
    tests/test_index.py).
    """
    nearest = jnp.argmax(dp, axis=1).astype(jnp.int32)
    near_sim = jnp.max(dp, axis=1)
    group = jnp.where(valid, nearest, n_pivots)   # padding after every group
    # lexsort: last key is primary
    return jnp.lexsort((-near_sim, group))


def interval_upper_bound(qp: Array, lo: Array, hi: Array) -> Array:
    """Max of Eq. 13 over ``b in [lo, hi]``, elementwise.

    ``ub(a, b) = cos(|arccos a − arccos b|)`` is maximal (=1) when ``b = a``
    is reachable; otherwise at the nearer interval end.  Shapes broadcast;
    the pivot axis is NOT reduced here.
    """
    at_ends = jnp.maximum(ub_mult(qp, lo), ub_mult(qp, hi))
    inside = (qp >= lo) & (qp <= hi)
    ub = jnp.where(inside, 1.0, at_ends)
    # inverted interval (lo > hi): the empty-block sentinel (+inf/-inf)
    # written for all-padding blocks — no reachable similarity, bound -inf.
    # (Raw ±inf through ub_mult yields NaN/+inf; jnp.where never leaks the
    # unselected branch, so the sentinel is mapped before anyone reduces.)
    return jnp.where(lo > hi, -jnp.inf, ub)


def block_upper_bound(qp: Array, dp_min: Array, dp_max: Array) -> Array:
    """Tightest block bound over pivots.

    qp: [m, P] query-pivot sims;  dp_min/dp_max: [P] one block's intervals.
    Returns [m]: ``min_p max_{b in [lo_p, hi_p]} ub_mult(qp_p, b)``.
    """
    per_pivot = interval_upper_bound(qp, dp_min[None, :], dp_max[None, :])
    return per_pivot.min(axis=-1)


def multipivot_block_cap(index: BlockIndex, qn: Array, *, n_pivots: int) -> Array:
    """Per-(query, block) joint multi-pivot upper bound ("cap").

    Projects the queries onto the first ``n_pivots`` rows of the index's
    orthonormalized pivot basis and takes, per block, the max of the joint
    row bound over the block's valid rows — a valid block bound because the
    max over members dominates each member (same argument as the interval
    bound).  Shrinks monotonically as ``n_pivots`` grows; at ``n_pivots = d``
    it equals the exact block max score.

    Args:
      index: a :class:`BlockIndex` with joint tables (``ortho is not None``).
      qn: [M, d] normalized queries.
      n_pivots: prefix depth ``1 <= n_pivots <= index.bound_table_width``.

    Returns [M, n_blocks] float32.
    """
    if index.ortho is None:
        raise ValueError("index has no joint bound tables (ortho is None)")
    j = int(n_pivots)
    if not 1 <= j <= index.bound_table_width:
        raise ValueError(
            f"n_pivots={j} outside [1, {index.bound_table_width}]")
    alpha = qn.astype(jnp.float32) @ index.ortho[:j].T          # [M, j]
    row_ub = joint_row_upper_bound(
        alpha, index.beta[:, :j], index.beta_nsq[:, j - 1])     # [M, n_pad]
    row_ub = jnp.where(index.valid[None, :], row_ub, -jnp.inf)
    m = row_ub.shape[0]
    return row_ub.reshape(m, index.n_blocks, -1).max(axis=-1)


def search(*args, **kwargs):
    """Removed: use :class:`repro.search.SearchEngine`.

    This was the pre-engine entry point; it then spent one release as a
    DeprecationWarning shim over the ``scan`` backend and is now a hard
    error — silently executing with a legacy default policy (natural
    block order, no τ warm-start) made benchmark numbers incomparable
    with the engine's.  The migration table is in docs/search-api.md.
    """
    raise TypeError(
        "repro.core.index.search() was removed. Use "
        "repro.search.SearchEngine: "
        "eng = SearchEngine(index, backend='scan'); "
        "sims, ids, stats = eng.search(queries, k). The migration table "
        "is in docs/search-api.md.")


@functools.partial(jax.jit, static_argnames=("k",))
def search_brute(index: BlockIndex, queries: Array, k: int):
    """Brute-force exact top-k (baseline; also the correctness oracle shape)."""
    qn = normalize(jnp.asarray(queries, jnp.float32))
    scores = qn @ index.db.T
    scores = jnp.where(index.valid[None, :], scores, -jnp.inf)
    sims, idx = jax.lax.top_k(scores, k)
    idx = jnp.where(idx >= 0, index.row_ids[jnp.maximum(idx, 0)], -1)
    return sims, idx.astype(jnp.int32)
