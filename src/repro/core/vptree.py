"""Vantage-point tree over cosine similarity — the paper-faithful baseline.

This is the CPU-idiomatic, pointer-style index family the paper targets
(Yianilos 1993 / Uhlmann 1991), operated *directly in similarity space* using
the paper's bounds, with a pluggable upper-bound function so the pruning
power of Eq. 13 (Mult) can be measured against the chord-metric bound
(reverse Eq. 7) and the cheap approximations — the experiment the paper
explicitly defers to future work (§4: "we will not investigate the actual
performance in a similarity index here").

Host-side numpy on purpose: data-dependent tree traversal is the thing that
does NOT map to TPU (DESIGN.md §2); the TPU-native equivalent is
:mod:`repro.core.index`.  Both are exact; ``benchmarks/pruning_power.py``
compares their pruning fractions.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from repro.core import ref

__all__ = ["VPTree", "UPPER_BOUNDS"]


def _interval_ub(ub_fn, a: float, lo: float, hi: float) -> float:
    """max over b in [lo, hi] of ub_fn(a, b); both paper UBs peak at b=a."""
    if lo <= a <= hi:
        return 1.0
    return max(float(ub_fn(a, lo)), float(ub_fn(a, hi)))


#: name -> similarity upper-bound function sim(x,y) <= ub(sim(x,z), sim(z,y))
UPPER_BOUNDS = {
    "mult": ref.ub_mult,       # Eq. 13 (tight, recommended)
    "euclid": ref.ub_euclid,   # via chord metric (reverse Eq. 7)
}


@dataclass
class _Node:
    vp: int                      # index of the vantage point
    mu: float = 1.0              # similarity threshold (near: sim >= mu)
    near: "_Node | None" = None
    far: "_Node | None" = None
    near_iv: tuple = (1.0, 1.0)  # (lo, hi) sim(vp, y) interval of near subtree
    far_iv: tuple = (-1.0, -1.0)
    bucket: np.ndarray | None = None  # leaf: explicit point ids


class VPTree:
    """Exact cosine kNN via VP-tree with similarity-domain pruning.

    Args:
      data: [n, d] raw vectors (normalized internally).
      leaf_size: bucket size at which recursion stops.
      seed: vantage-point sampling seed.
    """

    def __init__(self, data: np.ndarray, leaf_size: int = 16, seed: int = 0):
        self.data = ref.normalize(np.asarray(data, np.float64))
        self.n = self.data.shape[0]
        self._rng = np.random.default_rng(seed)
        self.leaf_size = leaf_size
        self.root = self._build(np.arange(self.n))

    # -- construction ------------------------------------------------------
    def _build(self, ids: np.ndarray) -> _Node | None:
        if ids.size == 0:
            return None
        if ids.size <= self.leaf_size:
            node = _Node(vp=int(ids[0]))
            node.bucket = ids
            return node
        vp_pos = int(self._rng.integers(ids.size))
        vp = int(ids[vp_pos])
        rest = np.delete(ids, vp_pos)
        sims = self.data[rest] @ self.data[vp]
        mu = float(np.median(sims))
        near_mask = sims >= mu
        near_ids, far_ids = rest[near_mask], rest[~near_mask]
        node = _Node(vp=vp, mu=mu)
        if near_ids.size:
            s = sims[near_mask]
            node.near_iv = (float(s.min()), float(s.max()))
            node.near = self._build(near_ids)
        if far_ids.size:
            s = sims[~near_mask]
            node.far_iv = (float(s.min()), float(s.max()))
            node.far = self._build(far_ids)
        return node

    # -- search ------------------------------------------------------------
    def knn(self, query: np.ndarray, k: int, *, bound: str = "mult"):
        """Exact top-k for one query.

        Returns (sims [k], ids [k], n_exact) where n_exact counts exact
        similarity computations (pruning power = 1 - n_exact/n).
        """
        ub_fn = UPPER_BOUNDS[bound]
        q = ref.normalize(query[None, :])[0]
        heap: list[tuple[float, int]] = []   # min-heap of (sim, id), size <= k
        n_exact = 0

        def offer(i: int):
            nonlocal n_exact
            s = float(q @ self.data[i])
            n_exact += 1
            if len(heap) < k:
                heapq.heappush(heap, (s, i))
            elif s > heap[0][0]:
                heapq.heapreplace(heap, (s, i))

        def tau() -> float:
            return heap[0][0] if len(heap) == k else -np.inf

        # best-first traversal: max-heap on subtree upper bound
        pq: list[tuple[float, int, _Node]] = []
        tie = 0

        def push(node: _Node | None, ub: float):
            nonlocal tie
            if node is not None and ub >= tau():
                heapq.heappush(pq, (-ub, tie, node))
                tie += 1

        push(self.root, 1.0)
        while pq:
            neg_ub, _, node = heapq.heappop(pq)
            if -neg_ub < tau():
                continue                      # stale entry, now prunable
            if node.bucket is not None:
                for i in node.bucket:
                    offer(int(i))
                continue
            a = float(q @ self.data[node.vp])  # exact sim to vantage point
            n_exact += 1
            if len(heap) < k or a > heap[0][0]:
                if len(heap) < k:
                    heapq.heappush(heap, (a, node.vp))
                else:
                    heapq.heapreplace(heap, (a, node.vp))
            push(node.near, _interval_ub(ub_fn, a, *node.near_iv))
            push(node.far, _interval_ub(ub_fn, a, *node.far_iv))

        top = sorted(heap, key=lambda t: (-t[0], t[1]))
        sims = np.array([t[0] for t in top])
        ids = np.array([t[1] for t in top], np.int64)
        return sims, ids, n_exact

    def knn_batch(self, queries: np.ndarray, k: int, *, bound: str = "mult"):
        """Batched wrapper; returns (sims [m,k], ids [m,k], mean_exact_frac)."""
        out_s, out_i, total = [], [], 0
        for q in np.asarray(queries, np.float64):
            s, i, ne = self.knn(q, k, bound=bound)
            out_s.append(s)
            out_i.append(i)
            total += ne
        frac = total / (len(queries) * self.n)
        return np.stack(out_s), np.stack(out_i), frac
