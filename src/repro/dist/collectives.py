"""Tiny exact-search collectives (run inside ``shard_map``).

The sharded datastore pattern: every shard computes its exact local top-k,
then the global top-k is the top-k of the union — ``O(devices * k)`` bytes
on the wire, negligible next to the score matmuls the pruning avoided.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

__all__ = ["topk_allgather_merge", "masked_topk_merge", "global_tau_merge"]


def topk_allgather_merge(sims: Array, ids: Array, k: int, axis_names):
    """Merge per-shard (sims [m, k], ids [m, k]) into the global top-k.

    All-gathers the candidate sets over ``axis_names`` (a mesh axis name or
    tuple of names) and re-runs ``top_k`` on the ``[m, shards * k]`` union.
    Exact: every shard's true local top-k is in the union, and the global
    top-k is a subset of the union of local top-k sets.
    """
    axis_names = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
    s = jax.lax.all_gather(sims, axis_names)        # [S, m, k]
    g = jax.lax.all_gather(ids, axis_names)
    m = s.shape[1]
    s = jnp.moveaxis(s, 0, 1).reshape(m, -1)        # [m, S * k]
    g = jnp.moveaxis(g, 0, 1).reshape(m, -1)
    top_s, pos = jax.lax.top_k(s, k)
    top_g = jnp.take_along_axis(g, pos, axis=1)
    return top_s, top_g


def masked_topk_merge(sims: Array, valid: Array, k: int, axis_names):
    """Mask-carrying top-k merge: per-shard candidate scores + validity.

    Like :func:`topk_allgather_merge` but the payload is a boolean
    validity mask instead of ids: all-gathers per-shard ``(sims [m, k],
    valid [m, k])`` candidate lists, masks invalid entries to ``-inf``,
    and returns the top-k of the union together with the surviving mask.
    ``valid[i, j]`` must be True iff ``sims[i, j]`` is the exact score of
    a *real* database row (warm-start prescans pad with ``-inf`` /
    ``False`` when a shard holds fewer than k candidates) — carrying the
    mask through the merge is what lets a consumer distinguish "k-th best
    of ≥ k real candidates" from "ran out of candidates", which a bare
    ``-inf`` convention cannot once scores are compared across shards.
    """
    # the id-merge already gathers an arbitrary payload column alongside
    # the scores; riding it with the mask as payload keeps one collective
    return topk_allgather_merge(jnp.where(valid, sims, -jnp.inf), valid, k,
                                axis_names)


def global_tau_merge(sims: Array, valid: Array, k: int, axis_names) -> Array:
    """Global τ broadcast: k-th best of the union of per-shard candidates.

    The returned ``tau [m]`` is the k-th highest *real* candidate score
    across every shard's warm-start list, or ``-inf`` for queries whose
    union holds fewer than k real candidates (no seed, never a wrong
    one).  Because each entry is the exact score of a real database row,
    τ is a true lower bound on the final **global** k-th best similarity
    — the exactness keystone of the sharded tree descent (DESIGN.md
    §3.6): any subtree or block with ``ub + margin < τ`` on *any* shard
    provably contains no global top-k member, so per-shard pruning
    against this one broadcast scalar per query is globally safe.
    """
    from repro.dist.compat import optimization_barrier

    top_s, top_v = masked_topk_merge(sims, valid, k, axis_names)
    # barrier before slicing the k-th column: the folded [k-1:k] slice
    # breaks XLA's TopkRewriter and the merge's top_k silently lowers to
    # a full sort (see repro.kernels.ref.kth_value for the measurement)
    top_s = optimization_barrier(top_s)
    return jnp.where(top_v[:, -1], top_s[:, -1], -jnp.inf)
