"""Tiny exact-search collectives (run inside ``shard_map``).

The sharded datastore pattern: every shard computes its exact local top-k,
then the global top-k is the top-k of the union — ``O(devices * k)`` bytes
on the wire, negligible next to the score matmuls the pruning avoided.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

__all__ = ["topk_allgather_merge"]


def topk_allgather_merge(sims: Array, ids: Array, k: int, axis_names):
    """Merge per-shard (sims [m, k], ids [m, k]) into the global top-k.

    All-gathers the candidate sets over ``axis_names`` (a mesh axis name or
    tuple of names) and re-runs ``top_k`` on the ``[m, shards * k]`` union.
    Exact: every shard's true local top-k is in the union, and the global
    top-k is a subset of the union of local top-k sets.
    """
    axis_names = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
    s = jax.lax.all_gather(sims, axis_names)        # [S, m, k]
    g = jax.lax.all_gather(ids, axis_names)
    m = s.shape[1]
    s = jnp.moveaxis(s, 0, 1).reshape(m, -1)        # [m, S * k]
    g = jnp.moveaxis(g, 0, 1).reshape(m, -1)
    top_s, pos = jax.lax.top_k(s, k)
    top_g = jnp.take_along_axis(g, pos, axis=1)
    return top_s, top_g
