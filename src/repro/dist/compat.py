"""JAX version compatibility shims.

The repo targets the modern ``jax.shard_map`` API (``check_vma`` /
``axis_names``); older installed versions only have
``jax.experimental.shard_map.shard_map`` (``check_rep`` / ``auto``).
:func:`shard_map` papers over the difference so call sites stay on the
modern spelling.

The multi-host helpers (:func:`make_process_local_array`,
:func:`replicate_to_mesh`, :func:`multiprocess_cpu_init`) wrap the
process-local array-assembly surface the distributed build relies on:
``jax.make_array_from_process_local_data`` exists in jax 0.4.37 but the
repo keeps one call site behind this shim (with a
``make_array_from_single_device_arrays`` fallback) so a jax without it —
or with a changed signature — only needs a fix here, and so CPU worker
processes get the one non-obvious 0.4.37 knob
(``jax_cpu_collectives_implementation='gloo'``) from a single place.
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["shard_map", "optimization_barrier", "make_process_local_array",
           "replicate_to_mesh", "multiprocess_cpu_init"]


@jax.custom_vjp
def optimization_barrier(x):
    """``jax.lax.optimization_barrier`` with a differentiation rule.

    Newer JAX differentiates the barrier natively (barrier on the
    cotangents); older versions raise NotImplementedError inside grad —
    this wrapper supplies that same rule everywhere.
    """
    return jax.lax.optimization_barrier(x)


def _opt_barrier_fwd(x):
    return optimization_barrier(x), None


def _opt_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


optimization_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names=None):
    """``jax.shard_map`` with fallback to the experimental API.

    ``axis_names`` selects the manual axes (partial-manual mode); on old
    JAX this maps to ``auto = mesh axes - axis_names``.
    """
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check_vma)
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _shard_map(f, **kw)


def multiprocess_cpu_init(coordinator_address: str, num_processes: int,
                          process_id: int) -> None:
    """``jax.distributed.initialize`` for multi-process CPU workers.

    On jax 0.4.37 the CPU client compiles multi-process programs only when
    a cross-process collectives implementation is configured, and the knob
    (``jax_cpu_collectives_implementation``) is an enum flag that does NOT
    read the environment — it must be set via ``jax.config.update`` before
    the backend is created.  Call this before any other jax API touches
    devices.  No-op on the collectives knob when the config is absent
    (newer jax selects a working CPU collectives impl itself).
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):
        pass  # newer jax: gloo is the default / knob renamed
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)


def make_process_local_array(sharding, local_data: np.ndarray, global_shape):
    """``jax.make_array_from_process_local_data`` behind one call site.

    ``local_data`` holds this process's rows of a ``global_shape`` array
    sharded by ``sharding``: the process's addressable slices of the
    global array, concatenated in ascending global order along every
    dimension where ``local_data`` is smaller than the global shape (the
    upstream function's documented mapping).  Dimensions where the local
    and global sizes match are read at global coordinates (replicated
    data must therefore be identical on every process).

    jax 0.4.37 ships the upstream function; the fallback assembles the
    same array from per-device ``device_put`` slices for a jax that
    predates it or changes its signature.
    """
    local_data = np.asarray(local_data)
    global_shape = tuple(global_shape)
    if hasattr(jax, "make_array_from_process_local_data"):
        return jax.make_array_from_process_local_data(sharding, local_data,
                                                      global_shape)
    # fallback: map each addressable device's global slice into local_data
    # coordinates (ascending-start order along shrunk dimensions)
    index_map = sharding.devices_indices_map(global_shape)
    addressable = [d for d in sharding.device_set
                   if d.process_index == jax.process_index()]
    offsets = []
    for dim in range(len(global_shape)):
        if local_data.shape[dim] == global_shape[dim]:
            offsets.append(None)  # global coordinates apply directly
        else:
            size_at = {}
            for d in addressable:
                idx = index_map[d][dim]
                start = idx.start or 0
                stop = idx.stop if idx.stop is not None else global_shape[dim]
                size_at[start] = stop - start
            starts = sorted(size_at)
            local_starts, ofs = {}, 0
            for start in starts:
                local_starts[start] = ofs
                ofs += size_at[start]
            offsets.append(local_starts)
    shards = []
    for d in addressable:
        sl = []
        for dim, idx in enumerate(index_map[d]):
            start = idx.start or 0
            stop = idx.stop if idx.stop is not None else global_shape[dim]
            if offsets[dim] is not None:
                length = stop - start
                start = offsets[dim][start]
                stop = start + length
            sl.append(slice(start, stop))
        shards.append(jax.device_put(local_data[tuple(sl)], d))
    return jax.make_array_from_single_device_arrays(global_shape, sharding,
                                                    shards)


def replicate_to_mesh(x, mesh):
    """A fully-replicated global array from identical per-process host data.

    Single-process: plain ``jnp.asarray`` (no behavior change on the
    existing paths).  Multi-process: every process passes the same host
    array and receives one global array replicated over ``mesh`` — the
    form ``jit``/``shard_map`` require for replicated operands when the
    mesh spans processes.
    """
    import jax.numpy as jnp

    if jax.process_count() == 1:
        return jnp.asarray(x)
    from jax.sharding import NamedSharding, PartitionSpec
    x = np.asarray(x)
    return make_process_local_array(NamedSharding(mesh, PartitionSpec()), x,
                                    x.shape)
