"""JAX version compatibility shims.

The repo targets the modern ``jax.shard_map`` API (``check_vma`` /
``axis_names``); older installed versions only have
``jax.experimental.shard_map.shard_map`` (``check_rep`` / ``auto``).
:func:`shard_map` papers over the difference so call sites stay on the
modern spelling.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "optimization_barrier"]


@jax.custom_vjp
def optimization_barrier(x):
    """``jax.lax.optimization_barrier`` with a differentiation rule.

    Newer JAX differentiates the barrier natively (barrier on the
    cotangents); older versions raise NotImplementedError inside grad —
    this wrapper supplies that same rule everywhere.
    """
    return jax.lax.optimization_barrier(x)


def _opt_barrier_fwd(x):
    return optimization_barrier(x), None


def _opt_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


optimization_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names=None):
    """``jax.shard_map`` with fallback to the experimental API.

    ``axis_names`` selects the manual axes (partial-manual mode); on old
    JAX this maps to ``auto = mesh axes - axis_names``.
    """
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check_vma)
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _shard_map(f, **kw)
