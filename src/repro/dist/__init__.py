"""Distribution utilities: logical-axis sharding rules, tiny collectives,
and elastic mesh reconstruction.

  sharding    — logical-name -> mesh-axis rules + ``shard`` constraint hints
  collectives — small exact-search collectives (top-k all-gather merge)
  elastic     — rebuild a mesh from surviving devices after node loss
  compat      — jax.shard_map API shim for older JAX versions
"""
from repro.dist import collectives, compat, elastic, sharding  # noqa: F401
