"""Logical-axis sharding rules (GSPMD constraint hints).

Model code never names mesh axes directly; it annotates activations with
*logical* axis names (``shd.shard(x, "batch", None, "heads", None)``) and
parameters are placed by :func:`param_spec`.  A rule table set once per
process (:func:`set_rules`) maps logical names to mesh axes; with no rules
active every annotation is a no-op, so the same model code runs unsharded
on a laptop and TP/FSDP-sharded on a pod.

Rules are plain data (``dict[str, str | tuple | None]``), so launchers can
tweak them (pure-DP ablations, serve-mode TP-resident weights) without
touching model code.  :func:`sanitize` drops axes that do not divide the
array dimension — annotations degrade to replication instead of erroring,
which is what makes smoke configs with tiny head counts runnable on any
mesh.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "set_rules", "active", "get_mesh", "rule", "default_rules",
    "shard", "sanitize", "param_spec", "path_name",
]

_MESH: Mesh | None = None
_RULES: dict[str, Any] | None = None


def set_rules(mesh: Mesh | None, rules: dict | None) -> None:
    """Install (or clear, with ``None, None``) the process-wide rule table."""
    global _MESH, _RULES
    _MESH = mesh
    _RULES = rules


def active() -> bool:
    return _MESH is not None and _RULES is not None


def get_mesh() -> Mesh | None:
    return _MESH


def rule(name: str):
    """Mesh axis (or axes tuple) for a logical name; None when unmapped."""
    if _RULES is None:
        return None
    return _RULES.get(name)


def default_rules(*, fsdp: bool = False, multi_pod: bool = False,
                  pure_dp: bool = False) -> dict:
    """The standard rule table.

    ``fsdp`` additionally shards parameters over the data axes (one dim per
    param, picked by :func:`param_spec`).  ``pure_dp`` unmaps every model
    dimension (data parallelism only — the MoE ablation path).
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    model = None if pure_dp else "model"
    return {
        "batch": dp,
        "heads": model,
        "kv_heads": model,
        "ffn": model,
        "vocab": model,
        "model_embed": None,      # activations stay replicated on d_model
        "expert_ffn": model,
        "fsdp": dp if fsdp else None,
    }


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _axes_in_mesh(mesh: Mesh, axes):
    if axes is None:
        return None
    tup = (axes,) if isinstance(axes, str) else tuple(axes)
    tup = tuple(a for a in tup if a in mesh.axis_names)
    if not tup:
        return None
    return tup[0] if len(tup) == 1 else tup


def sanitize(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec entries whose mesh axes are absent or do not divide the dim.

    Annotations degrade gracefully to replication — never an XLA error.
    """
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim_size, axes in zip(shape, dims):
        axes = _axes_in_mesh(mesh, axes)
        if axes is not None and dim_size % _axes_size(mesh, axes) != 0:
            axes = None
        out.append(axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x, *names):
    """Constrain ``x`` so dim ``i`` shards over the mesh axes of logical name
    ``names[i]`` (None = replicated).  No-op when no rules are active."""
    if not active():
        return x
    spec = P(*[rule(n) if n else None for n in names])
    spec = sanitize(spec, x.shape, _MESH)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


# ---------------------------------------------------------------------------
# parameter placement
# ---------------------------------------------------------------------------

def path_name(path) -> str:
    """jax tree key-path -> "a/b/0/c" string."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


#: parameter leaf names whose LAST dim is tensor-parallel (column parallel)
_TP_LAST = {"wq", "wk", "wv", "up", "gate", "wg", "in_proj", "w"}
#: parameter leaf names whose SECOND-TO-LAST dim is tensor-parallel (row par.)
_TP_FIRST = {"wo", "down", "out_proj"}


def param_spec(path, shape) -> P:
    """PartitionSpec for one parameter leaf (TP by name + optional FSDP).

    Works on both flat and scan-stacked ([L, ...]) parameters because only
    the trailing dims are matched.  The result still goes through
    :func:`sanitize` at placement time, so non-divisible dims replicate.
    """
    name = path_name(path)
    leaf = name.rsplit("/", 1)[-1]
    ndim = len(shape)
    spec: list = [None] * ndim
    model = rule("heads") or rule("ffn")
    if model is not None and ndim >= 2:
        if "embed" in name or "lm_head" in name:
            vocab = rule("vocab")
            if vocab is not None:
                # tok_embed [V, D] -> dim -2; lm_head/w [D, V] -> dim -1
                spec[-2 if "embed" in name else -1] = vocab
        elif leaf in _TP_LAST or any(s in name for s in ("experts/up",
                                                         "experts/gate")):
            spec[-1] = model
        elif leaf in _TP_FIRST or "experts/down" in name:
            spec[-2] = model
    fsdp_axes = rule("fsdp")
    if fsdp_axes is not None and _MESH is not None:
        size = _axes_size(_MESH, fsdp_axes)
        for dim in range(ndim):
            if spec[dim] is None and shape[dim] % size == 0 and shape[dim] > 1:
                spec[dim] = fsdp_axes
                break
    return P(*spec)
