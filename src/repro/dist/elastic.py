"""Elastic mesh reconstruction after node loss.

Checkpoints store full (unsharded) arrays, so a restore only needs *some*
valid mesh over the surviving devices; :func:`remesh` builds the largest
(data, model) mesh the survivors support, preferring to keep the model axis
at its previous width so TP layouts stay stable.
"""
from __future__ import annotations

import numpy as np
from jax.sharding import Mesh

__all__ = ["best_mesh", "remesh"]


def best_mesh(n: int, *, prefer_model: int | None = None) -> tuple[int, int]:
    """(data, model) shape for ``n`` surviving devices.

    ``model`` is the largest divisor of ``n`` that is ``<= prefer_model``
    (default: the most square split, ``floor(sqrt(n))``); the rest becomes
    the data axis.  Always satisfies ``data * model == n``.
    """
    if n <= 0:
        raise ValueError("best_mesh needs at least one device")
    if prefer_model is None:
        prefer_model = int(n ** 0.5)
    cap = max(1, min(prefer_model, n))
    model = max(d for d in range(1, cap + 1) if n % d == 0)
    return n // model, model


def remesh(devices, *, prefer_model: int | None = None) -> Mesh:
    """Build a ("data", "model") mesh over the surviving ``devices``."""
    devices = list(devices)
    data, model = best_mesh(len(devices), prefer_model=prefer_model)
    return Mesh(np.asarray(devices).reshape(data, model), ("data", "model"))
