"""Unified search runtime: one backend-dispatched exact-cosine-kNN API.

  engine   — :class:`SearchEngine` facade (normalization, τ warm-start,
             best-first ordering, stats, id mapping)
  backends — registry + the ``scan`` / ``kernel`` / ``sharded`` / ``brute``
             inner loops
  stats    — the one :class:`SearchStats` dataclass every path returns

See DESIGN.md §3 for the backend contract.
"""
from repro.search.backends import (available_backends, get_backend,  # noqa: F401
                                   register_backend)
from repro.search.engine import SearchEngine, auto_backend  # noqa: F401
from repro.search.stats import SearchStats  # noqa: F401
