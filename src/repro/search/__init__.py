"""Unified search runtime: one backend-dispatched exact-cosine-kNN API.

  engine   — :class:`SearchEngine` facade (normalization, τ warm-start,
             best-first ordering, stats, id mapping); ``.online()`` hands
             out the engine's :class:`MutableIndex` mutation handle
  backends — registry + the ``scan`` / ``kernel`` / ``sharded`` / ``brute``
             inner loops
  tree     — the hierarchical pivot-tree backend (``backend="tree"``):
             transitive Eq. 13 descent over an array-encoded balanced tree
  stats    — the one :class:`SearchStats` dataclass every path returns

This module is the package's canonical search surface: build with
``SearchEngine.build(db, ...)`` (local or ``distributed=True``), search
with ``engine.search(queries, k)``, mutate through ``engine.online()``.
See DESIGN.md §3 for the backend contract, §3.5 for the tree descent and
§3.9 for online mutation.
"""
from repro.core.online import MutableIndex
from repro.search.backends import (available_backends, get_backend,
                                   register_backend)
from repro.search.engine import SearchEngine, auto_backend
from repro.search.stats import SearchStats
from repro.search.tree import (ShardTreeArrays, TreeIndex,
                               build_shard_trees, build_tree, widen_tree)

__all__ = [
    "MutableIndex",
    "SearchEngine",
    "SearchStats",
    "ShardTreeArrays",
    "TreeIndex",
    "auto_backend",
    "available_backends",
    "build_shard_trees",
    "build_tree",
    "get_backend",
    "register_backend",
    "widen_tree",
]
