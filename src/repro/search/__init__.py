"""Unified search runtime: one backend-dispatched exact-cosine-kNN API.

  engine   — :class:`SearchEngine` facade (normalization, τ warm-start,
             best-first ordering, stats, id mapping)
  backends — registry + the ``scan`` / ``kernel`` / ``sharded`` / ``brute``
             inner loops
  tree     — the hierarchical pivot-tree backend (``backend="tree"``):
             transitive Eq. 13 descent over an array-encoded balanced tree
  stats    — the one :class:`SearchStats` dataclass every path returns

See DESIGN.md §3 for the backend contract and §3.5 for the tree descent.
"""
from repro.search.backends import (available_backends, get_backend,  # noqa: F401
                                   register_backend)
from repro.search.engine import SearchEngine, auto_backend  # noqa: F401
from repro.search.stats import SearchStats  # noqa: F401
from repro.search.tree import (ShardTreeArrays, TreeIndex,  # noqa: F401
                               build_shard_trees, build_tree)
