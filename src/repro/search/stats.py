"""The one stats object every search path returns.

Replaces the three ad-hoc shapes the backends used to hand back (the scan
path's ``{"block_prune_frac": ...}`` dict, the kernel path's bare
``computed.mean()`` scalar, and the sharded path's discarded stats) with a
single dataclass.  Dict-style access (``stats["block_prune_frac"]``,
``stats.items()``) is kept so existing benchmark/report code keeps working.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields

__all__ = ["SearchStats"]


@dataclass(frozen=True)
class SearchStats:
    """Per-call search statistics.

    Numeric fields are *lazy* jnp scalars (or tracers when the search ran
    inside an outer jit, e.g. the serving decode step): reading one forces
    the device sync, ignoring them costs nothing on the hot path.  Call
    ``float(...)`` / :meth:`as_dict` to materialize for logging.

    ``block_prune_frac`` is the engine-wide comparable number: the fraction
    of (query-or-query-tile, block) work units whose Eq. 13 upper bound
    proved them unnecessary.  For the scan backend the unit is a (query,
    index block) pair; for the kernel backend it is a (query tile, kernel
    tile) pair (``1 - tile_computed_frac``); for the sharded backend it is
    the mean over shards of the local scan fraction; brute force is 0 by
    definition.  The τ warm-start pre-scan (``ceil(k / block)`` blocks per
    query, DESIGN.md §3.4) is not counted as pruned or computed work.

    ``elem_prune_frac`` (requires ``element_stats``) is backend-uniform:
    the fraction of (query, valid row) pairs whose *individual* Eq. 13
    bound fell below the query's running τ at the moment the row's block
    was visited — the pruning a scalar per-point index (LAESA) would have
    achieved with the same pivots and visit order.  All backends report it
    over the same denominator ``n_queries * n_valid_rows`` (sharded: psum
    of counts over psum of valid rows); brute force is 0 by definition.

    ``tree_prune_frac`` (``tree`` backend, and ``sharded`` with per-shard
    trees) is the fraction of (query, block) pairs excluded by the
    *transitive* Eq. 13 descent alone — whole subtrees cut at an internal
    node before any leaf bound was evaluated (DESIGN.md §3.5; sharded:
    psum-weighted over shards, §3.6).  It is a component of
    ``block_prune_frac`` (descent-pruned blocks are also counted there),
    reported separately so the hierarchy's contribution is visible next
    to the flat leaf-stage pruning.

    ``tree_node_eval_frac`` (same backends) is the fraction of (query,
    valid tree node) pairs whose bound the descent actually had to
    evaluate — the flat scan is 1.0 at the leaf level by construction,
    so lower means the hierarchy is paying for itself.

    ``retraces`` is the number of jit traces (trace + XLA compile) this
    ``search`` call triggered through the engine's compiled-function
    cache: 0 means the fully-fused hot path was dispatch-cached (the
    steady state), 1 means this call paid one compilation (first call,
    or a new ``(backend, k, query shape, dtype, knobs)`` key).  It is a
    host ``int``, not a lazy scalar — the counter is a Python side effect
    that fires at trace time only.  ``None`` means the call went through
    a path the engine cannot count (the tree backend's host-orchestrated
    kernel-leaf stage).  Under an outer jit the reported value reflects
    trace-time work: the outer trace's first pass re-traces the fused
    callee, later cached outer calls never re-enter Python at all.

    ``n_pivots`` is the resolved joint-bound depth this engine searched
    with (the ``eq13_multi`` intersection of DESIGN.md §3.8): 0 means the
    single-formula ``eq13`` interval bound alone, ``None`` means the
    backend does not consume the knob (brute force).

    ``generation`` / ``decay_estimate`` (engines with an online
    :class:`~repro.core.online.MutableIndex` handle only) are the handle's
    mutation counter and its tracked pruning-decay estimate at the time of
    the call — host numbers, ``None`` on engines that never mutated
    (DESIGN.md §3.9).

    **Absent-stage fields are ``None``, never 0.**  A stage that did not
    run (no tree built, element stats off, not the kernel) reports
    ``None``; ``0.0`` always means the stage ran and pruned/skipped
    nothing.  Dashboards and regression gates can therefore tell "not
    run" from "pruned nothing" without knowing the backend.  Full
    glossary: docs/search-api.md.
    """

    backend: str
    n_queries: int
    k: int
    n_blocks: int
    block_prune_frac: float = 0.0
    tile_computed_frac: float | None = None
    elem_prune_frac: float | None = None
    tree_prune_frac: float | None = None
    tree_node_eval_frac: float | None = None
    warm_start: bool = False
    best_first: bool = False
    n_pivots: int | None = None
    retraces: int | None = None
    generation: int | None = None
    decay_estimate: float | None = None
    extras: dict = field(default_factory=dict)

    # -- dict-style compatibility with the old ad-hoc stats dicts ----------
    def __getitem__(self, key):
        if key in self.extras:
            return self.extras[key]
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def keys(self):
        return [f.name for f in fields(self) if f.name != "extras"] + list(self.extras)

    def items(self):
        return [(k, self[k]) for k in self.keys()]

    def as_dict(self) -> dict:
        return dict(self.items())
