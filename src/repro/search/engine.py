"""SearchEngine: one front door for every exact-cosine-search path.

The paper's Eq. 13 bound is shared infrastructure; what used to differ per
path (argument conventions, stats shapes, pruning plumbing, warm-start
availability) is now owned here.  Backends (``scan`` / ``kernel`` /
``sharded`` / ``brute`` / ``tree``) are pluggable and auto-selected by
device, mesh, and shape; each one is just an inner loop (see
:mod:`repro.search.backends`; the hierarchical ``tree`` backend is the
subsystem in :mod:`repro.search.tree`).

Usage::

    eng = SearchEngine.build(db, n_pivots=16, block_size=128)
    sims, ids, stats = eng.search(queries, k=10)
    stats.block_prune_frac     # one SearchStats shape for every backend

τ warm-start and best-first block ordering are engine policy (on by
default) and apply to every backend that can use them — they only change
*how fast τ rises*, never the result set, which stays bit-identical to
brute force (property-tested in tests/test_search_engine.py).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import BlockIndex, build_index
from repro.search import backends as _bk
from repro.search import defaults as _defaults
from repro.search.stats import SearchStats

__all__ = ["SearchEngine", "auto_backend"]

#: below this many padded rows the matmul is cheaper than any bookkeeping
_BRUTE_MAX_ROWS = 256

#: at this many blocks the flat O(n_blocks) bound pass starts to dominate
#: and the tree's O(survivors · depth) transitive descent wins
_TREE_MIN_BLOCKS = 256


def auto_backend(index: BlockIndex, mesh=None) -> str:
    """Pick a backend from device / mesh / shape.

    sharded  — index carries a stacked shard axis (built by
               ``build_sharded_index``) or a mesh was supplied;
    brute    — tiny datastore (bound evaluation would dominate);
    kernel   — on TPU, MXU-shaped work with VMEM-resident feature dim;
    tree     — deep datastores (≥ 256 blocks): the transitive Eq. 13
               descent (DESIGN.md §3.5) replaces the flat per-block bound
               pass, which at that depth dominates the work on clustered
               data;
    scan     — everywhere else (CPU/GPU, odd shapes): same pruning
               semantics, XLA-portable.
    """
    if index.db.ndim == 3 or mesh is not None:
        return "sharded"
    n_pad, d = index.db.shape
    if n_pad <= _BRUTE_MAX_ROWS:
        return "brute"
    if jax.default_backend() == "tpu" and d <= 4096:
        return "kernel"
    if index.dp_min.shape[-2] >= _TREE_MIN_BLOCKS:
        return "tree"
    return "scan"


@functools.partial(jax.jit, static_argnames=("k",))
def _pad_topk(sims, ids, *, k: int):
    """Widen ``[m, kk]`` results to ``[m, k]`` with the ``(-inf, -1)`` fill.

    Jitted (not host numpy) so it composes with tracers when the engine
    runs inside an outer jit and with multi-host global result arrays,
    which reject eager host-side ops.
    """
    pad = k - sims.shape[1]
    return (jnp.pad(sims, ((0, 0), (0, pad)), constant_values=-jnp.inf),
            jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1))


class SearchEngine:
    """Backend-dispatched exact top-k cosine search over a BlockIndex.

    Args:
      index: a :class:`BlockIndex` (or a shard-stacked one from
        ``build_sharded_index`` together with ``mesh``).
      backend: registered backend name, or ``"auto"`` (default).
      mesh / axis_names: mesh placement for the ``sharded`` backend.
      warm_start: seed each query's running k-th-best τ by exact-scoring
        its ``ceil(k / block)`` best-bound blocks before the main pass
        (every backend; the multi-block schedule is DESIGN.md §3.4, so the
        seeding engages for every ``k``, including ``k`` > block size).
      warm_start_blocks: widen the warm-start prescan to at least this many
        bound-ranked blocks.  ``None`` (default) defers to the time-tuned
        per-regime table in :mod:`repro.search.defaults` (whose own
        fallback is the ``ceil(k / block)`` floor; pass ``0`` to force the
        floor).  More blocks = a tighter τ seed at the cost of a larger
        prescan gather; never fewer than the floor, clamped to the block
        count.
      best_first: visit database blocks in descending upper-bound order
        (per query tile) so τ rises early and later blocks prune.
        ``None`` (default) defers to the time-tuned per-regime table
        (scan/tree backends on the swept platform; ``True`` elsewhere) —
        explicit ``True`` / ``False`` always wins.
      element_stats: default for ``search(..., element_stats=...)`` — also
        report ``SearchStats.elem_prune_frac``, the fraction of (query,
        valid row) pairs whose *individual* Eq. 13 bound prunes them
        (backend-uniform; see docs/search-api.md for the glossary).
      tree_shards: ``sharded`` backend only — run the transitive Eq. 13
        descent over a per-shard pivot tree (built lazily, one tree per
        shard over its local pivots) before each shard's leaf scan, with
        the global warm-start τ broadcast into every shard's descent
        (DESIGN.md §3.6).  ``True`` / ``False`` force it; ``None``
        (default) auto-enables once each shard holds ≥ 256 blocks — the
        same depth at which the single-device tree backend wins.  Ignored
        by non-sharded backends (the ``tree`` backend always descends).
      n_pivots: joint multi-pivot bound depth (DESIGN.md §3.8): before a
        block is admitted, the ``eq13_multi`` provider intersects the
        classic Eq. 13 interval bound with a joint projection bound over
        the first ``n_pivots`` rows of the index's orthonormalized pivot
        basis — tightest bound wins, validity is inherited pointwise.
        ``0`` disables the extra cap (the single-formula fast path);
        ``None`` (default) defers to the time-tuned per-regime table.
        Clamped to the index's bound-table width.  Consumed by the scan,
        kernel, tree and sharded backends; changing it re-keys the fused
        dispatch cache (one retrace), like every other knob.
      margin: fp32 guard added to bounds before comparing with τ.
      leaf_eval: tree-backend leaf stage — ``"scan"`` (portable, traceable
        inside an outer jit), ``"kernel"`` (compact the surviving leaves
        and run the fused Pallas kernel over just those rows;
        host-orchestrated), or ``"auto"`` (the time-tuned per-regime
        table when it binds, else kernel on TPU / scan elsewhere).
        Ignored by non-tree backends.
      bm / bn / sort_queries / interpret: kernel-backend tile options
        (ignored by other backends; ``bm`` / ``interpret`` also apply to
        the tree backend's kernel leaf stage).
    """

    def __init__(
        self,
        index: BlockIndex,
        *,
        backend: str = "auto",
        mesh=None,
        axis_names=None,
        warm_start: bool = True,
        warm_start_blocks: int | None = None,
        best_first: bool | None = None,
        element_stats: bool = False,
        tree_shards: bool | None = None,
        n_pivots: int | None = None,
        margin: float = 4e-7,
        leaf_eval: str = "auto",
        bm: int = 128,
        bn: int | None = None,
        sort_queries: bool = True,
        interpret: bool | None = None,
    ):
        self.index = index
        self.mesh = mesh
        self.axis_names = axis_names
        self.warm_start = warm_start
        self.element_stats = element_stats
        self.margin = margin
        self.bm = bm
        self.bn = bn
        self.sort_queries = sort_queries
        self.interpret = interpret
        self._sharded_fn = {}
        self._fn_cache = {}                     # fused dispatch cache
        self._traces = 0                        # jit traces observed, ever
        self._tree_index = None                 # built lazily by TreeBackend
        self._tree_valid_nodes = 0              # cached host count, ditto
        self._shard_tree = None                 # lazily by ShardedBackend
        #: bumped on every SHAPE-CHANGING online mutation (appended blocks,
        #: reoptimize); part of the fused-dispatch cache key, so
        #: shape-stable mutations keep hitting the cached executable while
        #: a grown index can never collide with a stale entry (whose
        #: donated scratch would have the old shape)
        self.index_epoch = 0
        self._online = None                     # MutableIndex handle, if any
        self.tree_shards = tree_shards
        # dp_min is [nb, P] or [S, nb, P] when shard-stacked; the sharded
        # tree auto-rule looks at the PER-SHARD depth
        per_shard_blocks = int(index.dp_min.shape[-2])
        if index.db.ndim == 3:
            self._tree_shards_enabled = (
                per_shard_blocks >= _TREE_MIN_BLOCKS
                if tree_shards is None else bool(tree_shards))
        else:
            self._tree_shards_enabled = False
        self.backend_name = (auto_backend(index, mesh)
                             if backend == "auto" else backend)
        # time-tuned per-regime defaults (repro.search.defaults): every
        # knob left at its sentinel resolves through the measured table;
        # the regime is detected from the index's Eq. 13 interval widths
        # (one host sync here, never on the search path).  Explicit knob
        # values and non-swept backends keep the static behavior.
        self.regime = (_defaults.detect_regime(index)
                       if self.backend_name in ("scan", "tree") else None)
        self.best_first = (bool(best_first) if best_first is not None
                           else _defaults.tuned_default("best_first",
                                                        self.regime))
        self.warm_start_blocks = (
            warm_start_blocks if warm_start_blocks is not None
            else _defaults.tuned_default("warm_start_blocks", self.regime))
        if leaf_eval == "auto":
            leaf_eval = (_defaults.tuned_default("leaf_eval", self.regime)
                         or "auto")
        self.leaf_eval = leaf_eval
        # joint-bound depth: sentinel -> tuned table; always clamped to the
        # index's table width (0 on pre-PR-7 indexes without the tables)
        table_width = index.bound_table_width
        if n_pivots is None:
            n_pivots = int(_defaults.tuned_default("n_pivots", self.regime)
                           or 0)
        self.n_pivots = max(0, min(int(n_pivots), table_width))
        # a flat 2D index cannot serve the sharded backend: without this
        # check the shard_map body peels a "shard axis" off the real data
        # and dies mid-trace in an opaque reshape TypeError.  Supplying a
        # mesh auto-selects "sharded", so this is an easy construction slip.
        if self.backend_name == "sharded" and index.db.ndim != 3:
            raise ValueError(
                "the 'sharded' backend needs a shard-stacked BlockIndex "
                "(leading [S, ...] shard axis); this index is flat 2D. "
                "Build one with SearchEngine.build(db, mesh=...) or "
                "repro.core.distributed.build_sharded_index(...), or drop "
                "mesh= / pass backend='scan' to search the flat index.")
        if index.db.ndim == 3 and self.backend_name != "sharded":
            raise ValueError(
                f"a shard-stacked BlockIndex is served by the 'sharded' "
                f"backend only (got backend={self.backend_name!r}); pass "
                f"mesh= (and backend='auto') to search it.")
        self.backend = _bk.get_backend(self.backend_name)
        # index.valid may be a multi-host global array (distributed build):
        # not fully addressable, so host-side np.asarray would throw — count
        # through jit instead (the summed scalar is replicated, int() works).
        v = index.valid
        if isinstance(v, jax.Array) and not v.is_fully_addressable:
            self.n_valid = int(jax.jit(jnp.sum)(v))
        else:
            self.n_valid = int(np.asarray(v).sum())
        self.n_blocks = per_shard_blocks
        #: total padded row slots across all shards — the most candidates
        #: any search can return; k above this pads with (-inf, -1)
        self.n_slots = int(index.db.shape[-2]) * (
            int(index.db.shape[0]) if index.db.ndim == 3 else 1)

    # ------------------------------------------------------------- building
    @classmethod
    def build(
        cls,
        db,
        *,
        n_pivots: int = 16,
        block_size: int = 128,
        pivot_method: str = "maxmin",
        reorder: bool = True,
        seed: int = 0,
        n_shards: int | None = None,
        mesh=None,
        distributed: bool = False,
        global_rows: int | None = None,
        bound_pivots: int | None = None,
        **engine_kw: Any,
    ) -> "SearchEngine":
        """Build the index and wrap it in an engine in one call.

        Pass ``mesh`` (and optionally ``n_shards``, default one shard per
        mesh device) to build a sharded datastore served by the
        ``sharded`` backend.

        ``n_pivots`` here is the *index* pivot count (interval tables and
        joint-bound table width); ``bound_pivots`` is the engine's search
        time ``n_pivots`` knob — the joint-bound depth actually
        intersected per query (``None`` defers to the tuned table).

        ``distributed=True`` (multi-process jax; needs ``mesh``) switches
        to the process-local build: ``db`` is then only THIS host's slice
        of the datastore — the rows its shards cover, see
        :func:`repro.core.distributed.local_shard_rows` — and
        ``global_rows`` is the total logical row count across all hosts
        (defaults to ``len(db)`` only when running single-process).  No
        host materializes the full datastore; search works unchanged
        (DESIGN.md §3.7).
        """
        if bound_pivots is not None:
            engine_kw["n_pivots"] = bound_pivots
        if distributed:
            if mesh is None:
                raise ValueError(
                    "SearchEngine.build(distributed=True) needs mesh= (the "
                    "global mesh the datastore shards across)")
            from repro.core.distributed import build_sharded_index_local
            if global_rows is None:
                if jax.process_count() > 1:
                    raise ValueError(
                        "SearchEngine.build(distributed=True) on a "
                        "multi-process mesh needs global_rows= (the total "
                        "datastore rows across all hosts; db holds only "
                        "this host's slice, so the split cannot be "
                        "inferred from it)")
                global_rows = int(np.asarray(db).shape[0])
            idx = build_sharded_index_local(
                np.asarray(db), mesh, global_rows=global_rows,
                axis_names=engine_kw.get("axis_names"), n_pivots=n_pivots,
                block_size=block_size, pivot_method=pivot_method)
            return cls(idx, mesh=mesh, **engine_kw)
        if mesh is not None:
            from repro.core.distributed import (build_sharded_index,
                                                place_sharded_index)
            n_shards = n_shards or mesh.devices.size
            idx = build_sharded_index(
                np.asarray(db), n_shards, n_pivots=n_pivots,
                block_size=block_size, pivot_method=pivot_method)
            idx = place_sharded_index(idx, mesh,
                                      engine_kw.get("axis_names"))
            return cls(idx, mesh=mesh, **engine_kw)
        idx = build_index(db, n_pivots=n_pivots, block_size=block_size,
                          pivot_method=pivot_method, reorder=reorder,
                          seed=seed)
        return cls(idx, **engine_kw)

    # ------------------------------------------------------------- mutation
    def online(self, **kw) -> "Any":
        """The engine's :class:`~repro.core.online.MutableIndex` handle
        (created on first use; one per engine).  Insert/delete/reoptimize
        through it — the engine's index, tree and dispatch caches stay
        consistent automatically.  Keyword args (``reoptimize_threshold``,
        ``auto_reoptimize``) are forwarded on first creation only.

        Sharded engines get a :class:`~repro.core.online.
        ShardedMutableIndex` — same surface, plus the deterministic
        cross-host row-placement protocol (DESIGN.md §3.10).
        """
        if self._online is None:
            from repro.core.online import MutableIndex, ShardedMutableIndex
            cls = (ShardedMutableIndex if self.index.db.ndim == 3
                   else MutableIndex)
            self._online = cls(self, **kw)
        elif kw:
            raise ValueError(
                "engine.online() already created its MutableIndex; "
                "per-handle options can only be set on the first call")
        return self._online

    def _apply_mutation(self, new_index: BlockIndex, *, n_valid: int,
                        shape_changed: bool, tree=None,
                        tree_valid_nodes: int | None = None,
                        shard_tree=None) -> None:
        """Install a mutated index (called by the
        :mod:`~repro.core.online` handles only).

        Shape-stable mutations keep every cached executable: the index is
        an *argument* of the fused callees, so fresh arrays of the same
        shape flow through the compiled code with zero retraces.  Shape
        changes (appended blocks, reoptimize) bump ``index_epoch``, drop
        the dispatch caches (their donated scratch buffers carry the old
        shapes) and invalidate the lazily built trees.

        ``tree`` / ``shard_tree`` carry the conservatively widened flat
        :class:`~repro.search.tree.TreeIndex` / stacked
        :class:`~repro.search.tree.ShardTreeArrays` twin for shape-stable
        inserts under a live tree.  Sharded deletes need no refresh at
        all: ``ShardTreeArrays`` does not embed the index, so the wide
        node caches keep serving the new index arrays as-is.
        """
        self.index = new_index
        self.n_valid = int(n_valid)
        if shape_changed:
            self.index_epoch += 1
            self._fn_cache.clear()
            self._sharded_fn.clear()
            self._tree_index = None
            self._tree_valid_nodes = 0
            self._shard_tree = None
            self.n_blocks = int(new_index.dp_min.shape[-2])
            self.n_slots = int(new_index.db.shape[-2]) * (
                int(new_index.db.shape[0]) if new_index.db.ndim == 3 else 1)
            return
        if shard_tree is not None:
            self._shard_tree = shard_tree
        if tree is not None:
            self._tree_index = tree
            if tree_valid_nodes is not None:
                self._tree_valid_nodes = int(tree_valid_nodes)
        elif self._tree_index is not None:
            # validity flipped under an existing tree (tombstone delete):
            # the node caches stay conservatively wide, but the tree must
            # serve the NEW index arrays
            self._tree_index = self._tree_index._replace(index=new_index)

    # ------------------------------------------------- fused dispatch cache
    def _note_trace(self):
        """Trace-time side effect: fused callables call this from inside
        their traced bodies, so it fires exactly once per jit trace and
        never on a cached dispatch — the retrace counter behind
        ``SearchStats.retraces``."""
        self._traces += 1

    def _knob_key(self):
        return (self.warm_start, self.warm_start_blocks, self.best_first,
                self.margin, self.leaf_eval, self.bm, self.bn,
                self.sort_queries, self.interpret, self.n_pivots)

    def _fused_callable(self, queries, kk: int, prune: bool,
                        element_stats: bool):
        """The cached one-dispatch callee for this call signature, or
        ``None`` when the backend (or this configuration) has no fused
        path and the legacy ``backend.run`` multi-dispatch is used.

        Keyed on ``(backend, k, query shape, dtype, knobs)``: a repeated
        call hits both this cache and the callee's compiled executable
        (0 retraces); changing ``k`` or the batch shape misses exactly
        once.  The cache entry also owns the donated scratch buffer the
        scan backend's best-first permutation cycles through.
        """
        make = getattr(self.backend, "make_fused", None)
        if make is None or len(getattr(queries, "shape", ())) != 2:
            return None
        # donated scratch needs a concrete buffer to cycle; under an outer
        # trace (serve decode) use the donation-free variant of the callee
        donate = (self.backend_name == "scan" and self.best_first
                  and not isinstance(queries, jax.core.Tracer))
        key = (self.backend_name, kk, tuple(queries.shape),
               str(queries.dtype), prune, element_stats, donate,
               self.index_epoch, self._knob_key())
        entry = self._fn_cache.get(key)
        if entry is None:
            fn = make(self, kk, prune=prune, element_stats=element_stats,
                      donate=donate)
            entry = [fn, None]          # None fn = remembered "unsupported"
            self._fn_cache[key] = entry
        if entry[0] is None:
            return None
        if not donate:
            return lambda q: entry[0](self.index, q)

        def call(q):
            scratch = entry[1]
            if scratch is None:
                nb, bs = self.n_blocks, self.index.block_size
                scratch = jnp.zeros((nb, bs, self.index.db.shape[-1]),
                                    jnp.float32)
            sims, ids, raw, scratch_out = entry[0](self.index, q, scratch)
            entry[1] = scratch_out      # cycle: donated next call
            return sims, ids, raw

        return call

    # ------------------------------------------------------------ searching
    def search(self, queries, k: int, *, prune: bool = True,
               element_stats: bool | None = None):
        """Exact top-k: ``(sims [m,k] f32, ids [m,k] i32, SearchStats)``.

        ``ids`` are original database row ids (-1 marks empty slots when
        ``k`` exceeds the number of valid rows).  The result set is
        identical to brute force for every backend and policy setting.
        ``element_stats`` defaults to the engine-level knob; pass True to
        also get ``SearchStats.elem_prune_frac`` for this call.

        ``k`` may exceed the datastore size: the backends run at
        ``min(k, n_slots)`` and the tail pads with ``(-inf, -1)`` — the
        same fill the valid-row contract above already uses, applied
        uniformly here so no backend's inner ``top_k`` sees a k wider
        than its score matrix.

        The steady-state hot path is one jitted dispatch: query prep, the
        τ prescan, the backend inner loop and the id mapping are fused
        into a per-``(backend, k, shape, knobs)`` cached callee (see
        ``SearchStats.retraces`` — 0 on a warm call).  Backends without a
        fusable configuration fall back to the legacy multi-dispatch
        ``backend.run``.
        """
        if element_stats is None:
            element_stats = self.element_stats
        if not hasattr(queries, "shape"):
            queries = jnp.asarray(queries)
        kk = min(k, self.n_slots)
        traces_before = self._traces
        fused = self._fused_callable(queries, kk, prune, element_stats)
        if fused is not None:
            sims, ids, raw = fused(queries)
            retraces = self._traces - traces_before
        else:
            sims, ids, raw = self.backend.run(
                self, queries, kk, prune=prune, element_stats=element_stats)
            # the sharded closure carries the trace hook; other legacy
            # paths (tree kernel-leaf) are multi-dispatch -> unknown
            retraces = (self._traces - traces_before
                        if self.backend_name == "sharded" else None)
        if kk < k:
            sims, ids = _pad_topk(sims, ids, k=k)
        stats = SearchStats(
            backend=self.backend_name,
            n_queries=int(queries.shape[0]),
            k=k,
            n_blocks=self.n_blocks,
            block_prune_frac=raw.get("block_prune_frac", 0.0),
            tile_computed_frac=raw.get("tile_computed_frac"),
            elem_prune_frac=raw.get("elem_prune_frac"),
            tree_prune_frac=raw.get("tree_prune_frac"),
            tree_node_eval_frac=raw.get("tree_node_eval_frac"),
            warm_start=self.warm_start,
            best_first=self.best_first,
            n_pivots=(None if self.backend_name == "brute"
                      else self.n_pivots),
            retraces=retraces,
            generation=(self._online.generation
                        if self._online is not None else None),
            decay_estimate=(self._online.decay_estimate
                            if self._online is not None else None),
            extras={k_: v for k_, v in raw.items()
                    if k_ not in ("block_prune_frac", "tile_computed_frac",
                                  "elem_prune_frac", "tree_prune_frac",
                                  "tree_node_eval_frac")},
        )
        return sims, ids, stats
