"""Pivot-tree exact search: the paper's tree-index claim, realized on arrays.

The paper's central promise (§4) is that its cosine triangle inequality
makes Cosine usable with *hierarchical* metric indexes — VP-trees, M-trees
— where the bound is applied **transitively**: one Eq. 13 evaluation at an
internal node prunes an entire subtree, not just one block.  After the
flat block engine (DESIGN.md §2) this module closes that gap with a
TPU-shaped tree:

* **Leaves are the block index's blocks.**  ``build_index`` already groups
  rows by nearest pivot (angularly coherent blocks with tight per-pivot
  similarity intervals); consecutive blocks are therefore angularly close,
  so a balanced binary tree over consecutive block *ranges* gives every
  internal node a meaningful interval.
* **Array encoding, not pointers.**  The tree is a heap: node 1 is the
  root, node ``i`` has children ``2i`` / ``2i+1``, leaves occupy slots
  ``[nl, 2nl)`` with ``nl`` the block count padded to a power of two.
  Per-node caches are two ``[2·nl, P]`` arrays (``node_lo`` / ``node_hi``,
  the union of descendant pivot intervals) plus a validity mask — build
  and batched descent are pure `jnp` and stay ``jit``-compatible.
* **Transitive pruning.**  A node's interval contains every descendant's
  interval, so its Eq. 13 interval bound dominates every descendant
  similarity: ``ub(node) < τ`` proves the whole subtree empty of top-k
  candidates.  The descent is level-synchronous (a boolean frontier per
  query), so it is one masked vector op per level instead of a pointer
  walk — DESIGN.md §3.5.
* **Leaves reuse the flat engine.**  Surviving leaves are handed to the
  existing inner loops: the ``scan`` loop (via its ``leaf_mask`` /
  ``ub_all`` / ``tau0`` hooks) or the Pallas kernel via the leaf-gather
  entry point (:mod:`repro.kernels.leaf_gather`), so τ warm-start,
  best-first ordering and element-stats plumbing all carry over.

Exactness: τ₀ seeds are true lower bounds on each query's final k-th best
(k-th best of *real* scored candidates), the node bound dominates every
descendant similarity, and the leaf stage is the already-property-tested
flat engine — so ``backend="tree"`` returns the identical result set to
brute force (tests/test_tree.py pins this with hypothesis sweeps).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.index import (BlockIndex, interval_upper_bound,
                              multipivot_block_cap)
from repro.kernels import ref as kref
from repro.search import backends as _bk

__all__ = ["TreeIndex", "ShardTreeArrays", "build_tree", "build_shard_trees",
           "tree_warm_start", "tree_warm_start_topk", "tree_descend",
           "tree_search", "widen_tree", "widen_shard_trees"]


class TreeIndex(NamedTuple):
    """Array-encoded balanced pivot tree over a :class:`BlockIndex`.

    Heap layout: node 1 is the root, node ``i`` has children ``2i`` and
    ``2i+1``; leaves sit at ``[nl, 2·nl)`` where ``nl`` is the block count
    rounded up to a power of two (leaf slot ``s`` = index block ``s`` for
    ``s < n_blocks``, invalid padding after).  ``node_lo`` / ``node_hi``
    cache the union of descendant per-pivot similarity intervals — the
    transitive Eq. 13 bound is evaluated on them exactly like a block
    bound.  A pytree of arrays: nests inside ``jit`` like the index does.
    """

    index: BlockIndex
    node_lo: Array     # [2*nl, P] union-of-descendants interval lower ends
    node_hi: Array     # [2*nl, P] union-of-descendants interval upper ends
    node_valid: Array  # [2*nl]    bool, True iff the subtree holds a real row

    @property
    def n_leaf_slots(self) -> int:
        return self.node_valid.shape[0] // 2

    @property
    def n_levels(self) -> int:
        """Tree depth: leaves live ``n_levels`` below the root."""
        return self.n_leaf_slots.bit_length() - 1

    @property
    def n_blocks(self) -> int:
        return self.index.n_blocks

    @property
    def block_size(self) -> int:
        return self.index.block_size

    @property
    def n_valid_nodes(self) -> int:
        """Host int: nodes whose subtree holds a real row (for stats)."""
        return int(np.asarray(self.node_valid).sum())


def _next_pow2(x: int) -> int:
    return 1 << (x - 1).bit_length() if x > 1 else 1


@functools.partial(jax.jit, static_argnames=("nl",))
def _tree_arrays(dp_min: Array, dp_max: Array, block_valid: Array, *, nl: int):
    """Bottom-up interval union into heap-ordered node arrays."""
    nb, p = dp_min.shape
    lo = jnp.full((2 * nl, p), jnp.inf, jnp.float32)
    hi = jnp.full((2 * nl, p), -jnp.inf, jnp.float32)
    valid = jnp.zeros((2 * nl,), bool)
    lo = lo.at[nl:nl + nb].set(
        jnp.where(block_valid[:, None], dp_min, jnp.inf))
    hi = hi.at[nl:nl + nb].set(
        jnp.where(block_valid[:, None], dp_max, -jnp.inf))
    valid = valid.at[nl:nl + nb].set(block_valid)
    sz = nl // 2
    while sz >= 1:
        c_lo = lo[2 * sz:4 * sz].reshape(sz, 2, p)
        c_hi = hi[2 * sz:4 * sz].reshape(sz, 2, p)
        c_va = valid[2 * sz:4 * sz].reshape(sz, 2)
        lo = lo.at[sz:2 * sz].set(c_lo.min(axis=1))
        hi = hi.at[sz:2 * sz].set(c_hi.max(axis=1))
        valid = valid.at[sz:2 * sz].set(c_va.any(axis=1))
        sz //= 2
    # empty subtrees keep the ±inf identity of the masked reduce — the same
    # empty-interval sentinel build_index writes for all-padding blocks.
    # Bound paths map an inverted interval to -inf (and node_valid masks
    # these nodes anyway), while widen_tree's scatter-min/max records the
    # first insert's EXACT interval instead of re-anchoring it at zero.
    return lo, hi, valid


def build_tree(index: BlockIndex) -> TreeIndex:
    """Build the balanced pivot tree over ``index``'s blocks.

    Cost is one min/max reduce per level over the cached block intervals —
    negligible next to ``build_index`` itself.  Shard-stacked indexes are
    not supported (the ``sharded`` backend owns those).
    """
    if index.db.ndim != 2:
        raise ValueError("build_tree needs a single-shard BlockIndex; "
                         "shard-stacked indexes are served by the 'sharded' "
                         "backend")
    nb, bs = index.n_blocks, index.block_size
    block_valid = index.valid.reshape(nb, bs).any(axis=1)
    nl = _next_pow2(nb)
    lo, hi, valid = _tree_arrays(index.dp_min, index.dp_max, block_valid,
                                 nl=nl)
    return TreeIndex(index, lo, hi, valid)


def widen_tree(tree: TreeIndex, index: BlockIndex, blocks: Array,
               dp_rows: Array) -> TreeIndex:
    """Conservatively widen the node interval caches along the root-to-leaf
    paths of freshly inserted rows (the online mutation path, DESIGN.md
    §3.9).

    Args:
      tree: the current :class:`TreeIndex` (its heap shape must match
        ``index`` — shape-changing mutations rebuild the tree instead).
      index: the post-insert :class:`BlockIndex` the widened tree serves.
      blocks: [r] i32 block id of each inserted row.
      dp_rows: [r, P] the inserted rows' pivot similarities.

    Every node on an affected path has its ``[node_lo, node_hi]`` union
    interval widened to contain the new rows' pivot similarities and is
    marked valid.  Widening only ever *loosens* intervals, so every Eq. 13
    node bound stays a true upper bound over its (grown) subtree — pruning
    degrades gracefully, exactness is untouched.  Scatter-min/max handles
    several inserts landing in the same block in one shot.
    """
    nl = tree.n_leaf_slots
    lo, hi, valid = tree.node_lo, tree.node_hi, tree.node_valid
    node = blocks.astype(jnp.int32) + nl
    for _ in range(tree.n_levels + 1):        # leaf ... root, inclusive
        lo = lo.at[node].min(dp_rows)
        hi = hi.at[node].max(dp_rows)
        valid = valid.at[node].set(True)
        node = node // 2
    return TreeIndex(index, lo, hi, valid)


def widen_shard_trees(tree: "ShardTreeArrays", blocks: Array,
                      dp_rows: Array, mask: Array) -> "ShardTreeArrays":
    """Per-shard :func:`widen_tree`: conservatively widen every shard's
    node caches along the root-to-leaf paths of its freshly inserted rows
    (the sharded online mutation path, DESIGN.md §3.10).

    Args:
      tree: shard-stacked node caches ``[S, 2·nl, P]`` / ``[S, 2·nl]``.
      blocks: [S, R] i32 per-shard block ids of the inserted rows, padded
        to a uniform width R across shards.
      dp_rows: [S, R, P] the rows' LOCAL pivot similarities (each shard's
        own pivots — the quantities its intervals cache).
      mask: [S, R] bool, False for the padding entries of short shards.

    Masked entries scatter to the out-of-range sentinel node ``2·nl`` and
    are dropped, so shards receiving fewer (or zero) rows this call stay
    untouched.  The widening argument is the flat one, applied per shard:
    every affected node's union interval grows to contain the new rows'
    similarities, so each shard's transitive Eq. 13 bounds stay true upper
    bounds over its (grown) subtrees.  Run under ``jit`` with the tree's
    own ``out_shardings`` so each device widens only its local tree.
    """
    two_nl = tree.node_valid.shape[1]
    nl = two_nl // 2
    levels = nl.bit_length() - 1

    def one(lo, hi, valid, blk, dp, mk):
        node = jnp.where(mk, blk.astype(jnp.int32) + nl, two_nl)
        for _ in range(levels + 1):        # leaf ... root, inclusive
            lo = lo.at[node].min(dp, mode="drop")
            hi = hi.at[node].max(dp, mode="drop")
            valid = valid.at[node].set(True, mode="drop")
            node = jnp.where(mk, node // 2, two_nl)
        return lo, hi, valid

    lo, hi, valid = jax.vmap(one)(tree.node_lo, tree.node_hi,
                                  tree.node_valid, blocks, dp_rows, mask)
    return ShardTreeArrays(lo, hi, valid)


class ShardTreeArrays(NamedTuple):
    """Per-shard tree node caches for the ``sharded`` backend.

    The same heap layout as :class:`TreeIndex` with a leading shard axis
    ``[S, ...]`` — one independent tree per shard, built over that shard's
    *local* pivots and blocks.  Kept separate from :class:`TreeIndex` so
    the shard_map closure can take ``(index, queries, tree_arrays)``
    without duplicating the index inside the tree pytree; inside the shard
    body the two recombine into a local :class:`TreeIndex`.
    """

    node_lo: Array     # [S, 2*nl, P]
    node_hi: Array     # [S, 2*nl, P]
    node_valid: Array  # [S, 2*nl]


def build_shard_trees(index: BlockIndex) -> ShardTreeArrays:
    """Build one pivot tree per shard of a stacked :class:`BlockIndex`.

    ``index`` must carry the leading shard axis produced by
    ``build_sharded_index`` (all shards share static shapes, so every
    shard's heap has the same ``nl`` and the result is one stacked array
    per cache).  Pure ``vmap`` over the per-shard interval caches — place
    the result with the same ``NamedSharding`` as the index so each device
    materializes only its own tree.  The ``sharded`` backend does this by
    calling the build under ``jit`` with explicit ``out_shardings``, which
    also makes it legal on a multi-host index (whose leaves are not
    addressable outside jit) with each host computing only its own
    shards' trees.
    """
    if index.db.ndim != 3:
        raise ValueError("build_shard_trees needs a shard-stacked BlockIndex "
                         "(leading [S, ...] axis from build_sharded_index); "
                         "single-shard indexes are served by build_tree")
    s, n_pad, _ = index.db.shape
    nb = index.dp_min.shape[1]
    bs = n_pad // nb
    block_valid = index.valid.reshape(s, nb, bs).any(axis=2)
    nl = _next_pow2(nb)
    lo, hi, valid = jax.vmap(
        lambda a, b, c: _tree_arrays(a, b, c, nl=nl))(
            index.dp_min, index.dp_max, block_valid)
    return ShardTreeArrays(lo, hi, valid)


def _gathered_bounds(qp: Array, lo: Array, hi: Array) -> Array:
    """Eq. 13 interval bound for per-query node gathers.

    qp: [m, P]; lo/hi: [m, W, P] -> [m, W].
    """
    per_pivot = interval_upper_bound(qp[:, None, :], lo, hi)
    return per_pivot.min(axis=-1)


def tree_warm_start_topk(tree: TreeIndex, qn: Array, qp: Array, k: int,
                         width: int):
    """Beam-descend to ``width`` best-bound leaves; return the candidate
    top-k, not just its k-th value.

    The flat engine's prescan (DESIGN.md §3.4) ranks *all* block bounds to
    pick its candidates; here the candidate leaves are found the way a
    metric tree finds them — a best-first descent.  A beam of ``width``
    nodes starts at the root; each level expands to the ``2·width``
    children and keeps the ``width`` highest Eq. 13 interval bounds, so
    only ``2·width·depth`` bounds are evaluated instead of ``n_blocks``.
    The reached leaves are exact-scored in one batched gather+matmul.

    Returns ``(scores [m, k], valid [m, k])``: the k highest exact
    similarities among the reached real candidates, descending, padded
    with ``-inf`` / ``valid=False`` when fewer than k real candidates were
    reached.  Single-device callers reduce this to a τ seed with
    :func:`tree_warm_start`; the ``sharded`` backend instead all-gathers
    the per-shard candidate lists and takes the k-th best of the *union*,
    which is what makes the broadcast τ a valid global bound even when
    individual shards hold fewer than k candidates (DESIGN.md §3.6).
    """
    idx = tree.index
    m = qp.shape[0]
    nl, depth = tree.n_leaf_slots, tree.n_levels
    nb, bs = idx.n_blocks, idx.block_size
    w = max(1, min(width, nb))
    # node id 0 is the empty sentinel (node_valid[0] is False)
    beam = jnp.zeros((m, w), jnp.int32).at[:, 0].set(1)
    for _ in range(depth):
        left = jnp.where(beam > 0, 2 * beam, 0)
        right = jnp.where(beam > 0, 2 * beam + 1, 0)
        cand = jnp.concatenate([left, right], axis=1)         # [m, 2w]
        ub = _gathered_bounds(qp, tree.node_lo[cand], tree.node_hi[cand])
        ok = tree.node_valid[cand] & (cand > 0)
        ub = jnp.where(ok, ub, -jnp.inf)
        _, sel = jax.lax.top_k(ub, w)
        beam = jnp.where(jnp.take_along_axis(ok, sel, axis=1),
                         jnp.take_along_axis(cand, sel, axis=1), 0)
    blocks = beam - nl                                        # leaf slot = block
    okb = (beam >= nl) & (blocks < nb)
    blocks = jnp.clip(blocks, 0, nb - 1)
    db_blocks = idx.db.reshape(nb, bs, -1)
    valid_blocks = idx.valid.reshape(nb, bs)
    blk = db_blocks[blocks].reshape(m, w * bs, -1)
    vb = (valid_blocks[blocks] & okb[:, :, None]).reshape(m, w * bs)
    scores = jnp.einsum("md,mcd->mc", qn, blk)
    scores = jnp.where(vb, scores, -jnp.inf)
    kk = min(k, w * bs)
    # barrier: single-device callers immediately slice the k-th column
    # (tree_warm_start), which would fold into top_k's internal sort+slice
    # and break XLA's TopkRewriter — a silent full-sort lowering (~10x on
    # CPU; see repro.kernels.ref.kth_value).  Pinning the [m, k] values
    # here protects every caller.
    from repro.dist.compat import optimization_barrier

    top_s, sel = jax.lax.top_k(scores, kk)
    top_s = optimization_barrier(top_s)
    top_v = jnp.take_along_axis(vb, sel, axis=1)
    if kk < k:                                 # shard smaller than k: pad
        top_s = jnp.pad(top_s, ((0, 0), (0, k - kk)),
                        constant_values=-jnp.inf)
        top_v = jnp.pad(top_v, ((0, 0), (0, k - kk)))
    return top_s, top_v


def tree_warm_start(tree: TreeIndex, qn: Array, qp: Array, k: int,
                    width: int) -> Array:
    """Tree-native τ seeding: the k-th best beam candidate, or -inf.

    Exactness does not depend on the beam finding the true best leaves:
    the k-th best of *any* set of real candidates is a valid lower bound
    on the final k-th best.  Queries whose reached leaves hold < k valid
    rows get -inf (no seed), mirroring ``tau_warm_start``.
    """
    m = qp.shape[0]
    w = max(1, min(width, tree.n_blocks))
    if w * tree.block_size < k:
        # fewer candidates than k even over the whole beam: no seed
        return jnp.full((m,), -jnp.inf, jnp.float32)
    scores, valid = tree_warm_start_topk(tree, qn, qp, k, width)
    return jnp.where(valid[:, -1], scores[:, -1], -jnp.inf)


def tree_descend(tree: TreeIndex, qp: Array, tau0: Array,
                 margin: float = 4e-7):
    """Level-synchronous transitive-bound descent (DESIGN.md §3.5).

    Per query a boolean frontier walks the heap top-down: a node is
    *evaluated* when its parent survived, and survives when its Eq. 13
    interval bound (+ fp ``margin``) reaches τ₀.  Because the node
    interval contains every descendant interval, a cut node provably
    excludes its whole subtree — the paper's bound applied transitively.

    Returns ``(leaf_alive [m, nb] bool, leaf_ub [m, nb], n_evals scalar)``:
    the surviving-leaf mask, the leaf-level bound matrix (identical to
    what the flat engine would have computed — reused by the leaf stage),
    and the number of (query, node) bound evaluations actually needed — a
    pointer implementation's cost, which the dense masked form models
    (this repo computes-and-masks; the statistic is what a scalar host or
    a scalar-prefetch kernel skips).
    """
    m = qp.shape[0]
    nl, depth, nb = tree.n_leaf_slots, tree.n_levels, tree.n_blocks
    alive = jnp.ones((m, 1), bool) & tree.node_valid[1]       # root frontier
    evals = jnp.full((), float(m), jnp.float32)               # root bound
    ub = None
    for level in range(1, depth + 1):
        base = 1 << level
        lo = tree.node_lo[base:2 * base]                      # [2^l, P]
        hi = tree.node_hi[base:2 * base]
        va = tree.node_valid[base:2 * base]
        ub = kref.block_bounds(qp, lo, hi)                    # [m, 2^l]
        evaluated = jnp.repeat(alive, 2, axis=1) & va[None, :]
        alive = evaluated & (ub + margin >= tau0[:, None])
        evals = evals + evaluated.sum().astype(jnp.float32)
    if depth == 0:                                            # single block
        ub = kref.block_bounds(qp, tree.node_lo[1:2], tree.node_hi[1:2])
        alive = alive & (ub + margin >= tau0[:, None])
    return alive[:, :nb], ub[:, :nb], evals


def _seed_and_descend(tree: TreeIndex, qn: Array, qp: Array, k: int, *,
                      warm_start: bool, warm_start_blocks: int | None,
                      margin: float, tau_merge=None):
    """Beam seed → transitive descent → flat reseed, the one sequence every
    leaf stage shares (exactness-critical; keep it in one place — the
    sharded per-shard stage runs it too, see ``core/distributed.py``).

    Returns ``(tau0 [m] or None, leaf_alive [m, nb], leaf_ub [m, nb],
    n_evals)``.  The flat reseed is a *second* prescan gather+matmul on
    top of the beam's — a deliberate cost (O(k·d) per query, vs the
    O(n·d) leaf stage): scoring the flat top-bound blocks too is what
    guarantees τ₀ ≥ the scan backend's seed, hence the tree's pruned set
    ⊇ the scan's (DESIGN.md §3.5).  It reuses the descent's leaf-level
    bound matrix, so no bounds are re-evaluated.

    ``tau_merge`` turns the beam's candidate list into the descent's τ
    seed.  Default: the local k-th best (:func:`tree_warm_start`'s
    semantics).  The sharded backend passes the mask-carrying all-gather
    reduction instead, so the seed becomes the k-th best of the union of
    every shard's candidates — the broadcast global τ of DESIGN.md §3.6
    (any k-th-best-of-real-candidates is a valid lower bound, so the
    exactness argument is unchanged; the flat reseed below then only ever
    raises it further).
    """
    idx = tree.index
    m = qn.shape[0]
    nb, bs = idx.n_blocks, idx.block_size
    tau0 = jnp.full((m,), -jnp.inf, jnp.float32)
    n_pre = _bk.prescan_blocks(k, bs, nb, warm_start_blocks)
    if warm_start:
        if tau_merge is None:
            tau0 = tree_warm_start(tree, qn, qp, k, n_pre)
        else:
            cand_s, cand_v = tree_warm_start_topk(tree, qn, qp, k, n_pre)
            tau0 = tau_merge(cand_s, cand_v)
    leaf_alive, leaf_ub, evals = tree_descend(tree, qp, tau0, margin)
    if warm_start:
        tau_flat = _bk.tau_warm_start(
            qn, idx.db.reshape(nb, bs, -1), idx.valid.reshape(nb, bs),
            leaf_ub, k, n_pre)
        tau0 = jnp.maximum(tau0, tau_flat)
    return (tau0 if warm_start else None), leaf_alive, leaf_ub, evals


@functools.partial(
    jax.jit,
    static_argnames=("k", "prune", "warm_start", "best_first", "element_stats",
                     "warm_start_blocks", "n_pivots"),
)
def tree_search(
    tree: TreeIndex,
    qn: Array,
    qp: Array,
    k: int,
    *,
    prune: bool = True,
    margin: float = 4e-7,
    warm_start: bool = True,
    best_first: bool = True,
    element_stats: bool = False,
    warm_start_blocks: int | None = None,
    n_pivots: int = 0,
):
    """Full tree search with the scan leaf stage, one jitted unit.

    Beam warm start → transitive descent → flat leaf stage over the
    survivors.  The leaf stage receives the descent's leaf-level bound
    matrix (no re-evaluation), the surviving-leaf mask, and a τ₀ that is
    the max of the beam seed and the flat prescan seed computed from that
    same bound matrix — both are true lower bounds, and taking the max
    guarantees the tree's running τ never starts below the scan
    backend's, so its pruned set is a superset of the scan's.

    Returns ``(top_s, pos, blk_pruned, elem_pruned, tree_pruned,
    node_evals)`` — the first four exactly as :func:`scan_search`, plus
    the count of (query, block) pairs the descent alone excluded and the
    number of (query, node) bound evaluations the descent needed.
    """
    idx = tree.index

    if prune:
        tau0, leaf_alive, leaf_ub, evals = _seed_and_descend(
            tree, qn, qp, k, warm_start=warm_start,
            warm_start_blocks=warm_start_blocks, margin=margin)
        if n_pivots > 0:
            # eq13_multi at the leaf level: tighten the descent's leaf
            # bound matrix with the joint projection cap before the leaf
            # scan consumes it.  The descent itself (and tree_prune_frac)
            # stays interval-only — the caps are leaf-granular tables.
            leaf_ub = jnp.minimum(
                leaf_ub, multipivot_block_cap(idx, qn, n_pivots=n_pivots))
    else:
        tau0, leaf_alive, leaf_ub = None, None, None
        evals = jnp.zeros((), jnp.float32)

    top_s, pos, blk_pruned, elem_pruned = _bk.scan_search(
        idx, qn, qp, k, prune=prune, margin=margin, warm_start=False,
        best_first=best_first, element_stats=element_stats,
        tau0=tau0, ub_all=leaf_ub, leaf_mask=leaf_alive)
    tree_pruned = ((~leaf_alive).sum().astype(jnp.float32) if prune
                   else jnp.zeros((), jnp.float32))
    return top_s, pos, blk_pruned, elem_pruned, tree_pruned, evals


@_bk.register_backend("tree")
class TreeBackend:
    """Hierarchical pivot-tree backend (``backend="tree"``).

    Builds (and caches on the engine) a :class:`TreeIndex` over the
    engine's ``BlockIndex`` on first use.  The leaf stage is selected by
    ``SearchEngine(leaf_eval=...)``: ``"scan"`` (portable, traceable
    inside an outer jit), ``"kernel"`` (compacts the union of surviving
    leaves with :mod:`repro.kernels.leaf_gather` and runs the fused Pallas
    kernel over just those rows — host-orchestrated, so not callable from
    inside an outer jit), or ``"auto"`` (kernel on TPU, scan elsewhere).
    The kernel leaf stage requires ``k <= block_size`` and pruning on;
    otherwise it falls back to the scan leaf stage.
    """

    name = "tree"

    def _tree(self, eng) -> TreeIndex:
        tree = getattr(eng, "_tree_index", None)
        if tree is None:
            tree = build_tree(eng.index)
            eng._tree_index = tree
            # constant per tree; cache the host sync so per-call stats stay
            # lazy jnp scalars (the engine may be traced inside a decode jit)
            eng._tree_valid_nodes = tree.n_valid_nodes
        return tree

    @staticmethod
    def _resolve_leaf_eval(eng) -> str:
        if eng.leaf_eval != "auto":
            return eng.leaf_eval
        # same VMEM guard as the flat kernel's auto-selection: the
        # Pallas kernel keeps the whole feature dim resident
        return ("kernel" if jax.default_backend() == "tpu"
                and eng.index.db.shape[-1] <= 4096 else "scan")

    def make_fused(self, eng, k, *, prune, element_stats, donate):
        """One-dispatch callee: prep + beam seed + descent + leaf scan +
        id map in one jit.  ``None`` for the kernel-leaf configuration —
        that stage is host-orchestrated (data-dependent compaction) and
        keeps the legacy multi-dispatch path."""
        leaf_eval = self._resolve_leaf_eval(eng)
        if leaf_eval == "kernel" and prune and k <= eng.index.block_size:
            return None
        self._tree(eng)                 # host-side build, outside the jit
        note = eng._note_trace
        margin, warm_start = eng.margin, eng.warm_start
        best_first, wsb = eng.best_first, eng.warm_start_blocks
        n_piv = eng.n_pivots

        @jax.jit
        def fused(index, tree, queries):
            note()
            qn, qp = _bk.prep_queries(index, queries)
            m, nb = qn.shape[0], tree.n_blocks
            top_s, pos, blk_pruned, elem_pruned, tree_pruned, evals = \
                tree_search(
                    tree, qn, qp, k, prune=prune, margin=margin,
                    warm_start=warm_start, best_first=best_first,
                    element_stats=element_stats, warm_start_blocks=wsb,
                    n_pivots=n_piv)
            ids = _bk.map_row_ids(index.row_ids, pos)
            raw = {
                "block_prune_frac": blk_pruned / (m * nb),
                "tree_levels": tree.n_levels,
            }
            if prune:
                # denominators traced, not captured: online mutation widens
                # the tree / flips validity without retracing this callee
                n_valid_nodes = jnp.maximum(tree.node_valid.sum(), 1)
                raw["tree_prune_frac"] = tree_pruned / (m * nb)
                raw["tree_node_eval_frac"] = evals / (m * n_valid_nodes)
            if element_stats:
                n_valid_rows = jnp.maximum(index.valid.sum(), 1)
                raw["elem_prune_frac"] = elem_pruned / (m * n_valid_rows)
            return top_s, ids, raw

        # the tree is fetched PER CALL (not bound at make time): a
        # shape-stable mutation swaps eng._tree_index for a widened twin
        # with identical array shapes, so the cached executable is reused
        # with the fresh arrays — no retrace, no stale intervals
        return lambda index, queries: fused(index, self._tree(eng), queries)

    def run(self, eng, queries, k, *, prune=True, element_stats=False):
        tree = self._tree(eng)
        qn, qp = _bk.prep_queries(eng.index, queries)
        m, nb = qn.shape[0], tree.n_blocks

        leaf_eval = self._resolve_leaf_eval(eng)
        if leaf_eval == "kernel" and prune and k <= tree.block_size:
            return self._run_kernel_leaves(eng, tree, qn, qp, k,
                                           element_stats=element_stats)

        top_s, pos, blk_pruned, elem_pruned, tree_pruned, evals = tree_search(
            tree, qn, qp, k, prune=prune, margin=eng.margin,
            warm_start=eng.warm_start, best_first=eng.best_first,
            element_stats=element_stats,
            warm_start_blocks=eng.warm_start_blocks,
            n_pivots=eng.n_pivots)
        ids = _bk.map_row_ids(eng.index.row_ids, pos)
        raw = {
            "block_prune_frac": blk_pruned / (m * nb),
            "tree_levels": tree.n_levels,
        }
        if prune:
            # absent-stage contract: with prune off the descent never ran,
            # so the tree fracs stay None (engine raw.get), never 0.0
            raw["tree_prune_frac"] = tree_pruned / (m * nb)
            raw["tree_node_eval_frac"] = evals / (
                m * max(1, eng._tree_valid_nodes))
        if element_stats:
            raw["elem_prune_frac"] = elem_pruned / (m * max(1, eng.n_valid))
        return top_s, ids, raw

    def _run_kernel_leaves(self, eng, tree: TreeIndex, qn, qp, k, *,
                           element_stats: bool):
        """Descent, then the Pallas kernel over the compacted survivors."""
        from repro.kernels import leaf_gather

        idx = tree.index
        m, nb, bs = qn.shape[0], tree.n_blocks, tree.block_size
        tau0, leaf_alive, _, evals = _seed_and_descend(
            tree, qn, qp, k, warm_start=eng.warm_start,
            warm_start_blocks=eng.warm_start_blocks, margin=eng.margin)
        # tree_prune_frac stays descent-only: snapshot before any cap
        # refinement below changes the compaction mask
        tree_pruned = (~leaf_alive).sum().astype(jnp.float32)
        if eng.n_pivots > 0 and tau0 is not None and not element_stats:
            # eq13_multi refinement of the compaction: leaves whose joint
            # cap cannot reach the τ seed never enter the kernel grid.
            # Skipped under element_stats — that statistic's non-kept-block
            # accounting relies on every compacted-away row being provably
            # under its *interval* bound, which the cap does not imply.
            cap = multipivot_block_cap(idx, qn, n_pivots=eng.n_pivots)
            leaf_alive = leaf_alive & (cap + eng.margin >= tau0[:, None])

        # host-side compaction: the union over the query batch of surviving
        # leaves is the data-dependent part, so the kernel grid shrinks to
        # the blocks that can still matter (ascending order keeps valid
        # rows a prefix — build_index places padding rows last)
        union = np.asarray(jax.device_get(leaf_alive.any(axis=0)))
        keep_np = np.nonzero(union)[0].astype(np.int32)
        if keep_np.size == 0:
            keep_np = np.zeros((1,), np.int32)                # degenerate
        keep = jnp.asarray(keep_np)
        if eng.sort_queries:
            # angularly coherent query tiles: the tile-level skip is an OR
            # over the bm queries, so nearest-pivot grouping lets it fire
            perm = _bk.query_sort_perm(qp)
            qn, qp = qn[perm], qp[perm]
            if tau0 is not None:
                tau0 = tau0[perm]
        sims, pos, computed, elem = leaf_gather.gathered_topk(
            idx, keep, qn, qp, tau0,
            n_keep=int(keep_np.size), k=k, bm=eng.bm, margin=eng.margin,
            interpret=(jax.default_backend() == "cpu" if eng.interpret is None
                       else eng.interpret),
            element_stats=element_stats, best_first=eng.best_first)
        if eng.sort_queries:
            inv = jnp.argsort(perm)
            sims, pos = sims[inv], pos[inv]
        ids = _bk.map_row_ids(idx.row_ids, pos)

        m_tiles = computed.shape[0]
        computed_sum = computed.astype(jnp.float32).sum()
        raw = {
            # over the FULL (query tile, block tile) grid: compacted-away
            # tiles were never dispatched, which is the whole point
            "block_prune_frac": 1.0 - computed_sum / (m_tiles * nb),
            "tile_computed_frac": computed_sum / (m_tiles * nb),
            "tree_prune_frac": tree_pruned / (m * nb),
            "tree_node_eval_frac": evals / (m * max(1, eng._tree_valid_nodes)),
            "tree_levels": tree.n_levels,
        }
        if element_stats:
            # rows in never-kept blocks were proven prunable by the descent
            # (their individual Eq. 13 bound sits under the node bound < τ0)
            valid_counts = idx.valid.reshape(nb, bs).sum(axis=1)
            nonkept = valid_counts.sum() - valid_counts[keep].sum()
            total = elem.astype(jnp.float32).sum() + m * nonkept
            raw["elem_prune_frac"] = total / (m * max(1, eng.n_valid))
        return sims, ids, raw
