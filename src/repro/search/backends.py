"""Search backends: the bare inner loops behind :class:`SearchEngine`.

Every backend implements one method::

    run(engine, queries, k, *, prune, element_stats)
        -> (sims [m, k] f32, ids [m, k] i32 original row ids, raw stats dict)

and registers itself under a name with :func:`register_backend`.  The
engine owns everything else — query normalization, pivot-similarity
computation, τ warm-start policy, best-first ordering policy, id mapping,
and :class:`~repro.search.stats.SearchStats` assembly — so a backend is
only its compute strategy:

  ``scan``    pure-JAX ``lax.scan`` over blocks (masked matmuls; portable)
  ``kernel``  fused Pallas kernel (``@pl.when``-skipped tiles; TPU-native)
  ``sharded`` per-device scan + tiny all-gather top-k merge (mesh required)
  ``brute``   full matmul + top-k (baseline / tiny datastores)

The shared helpers here (τ warm-start seeding, best-first block
permutation) are what the refactor lifted out of the kernel-only path so
that *every* backend benefits — DESIGN.md §3.1 (warm-start), §3.2
(best-first), §3.3 (the backend contract), §3.4 (the multi-block
warm-start schedule and its exactness argument).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.bounds import ub_mult
from repro.core.index import (BlockIndex, block_upper_bound,
                              multipivot_block_cap)
from repro.core.pivots import normalize
from repro.kernels import cosine_topk
from repro.kernels import ref as kref

__all__ = [
    "register_backend", "get_backend", "available_backends",
    "prep_queries", "map_row_ids", "scan_search", "kernel_search",
    "brute_search", "tau_warm_start", "prescan_blocks", "coarsen_intervals",
    "query_sort_perm",
]

_REGISTRY: dict[str, object] = {}


def register_backend(name: str):
    """Class decorator: register a backend under ``name`` (instantiated)."""
    def deco(cls):
        _REGISTRY[name] = cls()
        return cls
    return deco


def get_backend(name: str):
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown search backend {name!r}; "
            f"registered: {available_backends()}") from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# shared jitted pieces (engine-owned plumbing)
# ---------------------------------------------------------------------------

@jax.jit
def prep_queries(index: BlockIndex, queries: Array):
    """Normalize queries and compute query-pivot similarities once."""
    qn = normalize(jnp.asarray(queries, jnp.float32))
    return qn, qn @ index.pivots.T


@jax.jit
def map_row_ids(row_ids: Array, pos: Array) -> Array:
    """Padded/reordered positions -> original row ids (-1 stays -1)."""
    return jnp.where(pos >= 0, row_ids[jnp.maximum(pos, 0)], -1)


def coarsen_intervals(dp_min: Array, dp_max: Array, factor: int):
    """Merge ``factor`` consecutive index blocks into one kernel tile."""
    nb, p = dp_min.shape
    assert nb % factor == 0, (nb, factor)
    lo = dp_min.reshape(nb // factor, factor, p).min(axis=1)
    hi = dp_max.reshape(nb // factor, factor, p).max(axis=1)
    return lo, hi


def prescan_blocks(k: int, block_rows: int, n_blocks: int,
                   warm_start_blocks: int | None = None) -> int:
    """Static prescan width: how many bound-ranked blocks τ seeding scores.

    The floor ``ceil(k / block_rows)`` is the fewest blocks that can hold k
    candidates — this is what lets warm-start engage for every ``k`` instead
    of auto-disabling when ``k`` exceeds the block size (DESIGN.md §3.4).
    ``warm_start_blocks`` only ever *widens* the prescan (a tighter seed at
    the cost of a larger gather); the result is clamped to ``n_blocks``.
    """
    n_pre = -(-k // max(1, block_rows))
    if warm_start_blocks is not None:
        n_pre = max(n_pre, warm_start_blocks)
    return max(1, min(n_pre, n_blocks))


def tau_warm_start(qn: Array, db_blocks: Array, valid_blocks: Array,
                   ub: Array, k: int, n_pre: int = 1) -> Array:
    """Seed each query's running k-th-best from its ``n_pre`` best-bound blocks.

    One batched ``[m, n_pre * bs] x d`` matmul: gather the ``n_pre`` blocks
    whose Eq. 13 upper bounds are highest for each query (bound-ranked via
    ``top_k``), exact-score them together, and take the k-th best of the
    merged candidate set.  The seed is a true lower bound on the final τ
    *achieved by k real candidates of those blocks*, so seeding every top-k
    slot with it (minus an ulp so ties displace seeds) cannot evict a true
    neighbor (DESIGN.md §3.4).  Queries whose prescanned blocks hold < k
    valid rows get -inf (no seeding).

    ``n_pre`` is static; size it with :func:`prescan_blocks` so that
    ``n_pre * bs >= k`` whenever the database allows.  ``ub`` is [m, nb] at
    the same block granularity as ``db_blocks`` [nb, bs, d].
    """
    m = qn.shape[0]
    nb, bs, d = db_blocks.shape
    n_pre = max(1, min(n_pre, nb))
    if n_pre * bs < k:
        # fewer candidates than k even over the whole prescan: no seed
        return jnp.full((m,), -jnp.inf, jnp.float32)
    best = jax.lax.top_k(ub, n_pre)[1]                  # [m, n_pre]
    blk = db_blocks[best].reshape(m, n_pre * bs, d)
    vb = valid_blocks[best].reshape(m, n_pre * bs)
    scores = jnp.einsum("md,mcd->mc", qn, blk)
    scores = jnp.where(vb, scores, -jnp.inf)
    # kth_value, not top_k(...)[0][:, -1]: the naive slice breaks XLA's
    # TopkRewriter and this line becomes a full sort (~10x, see kref)
    tau = kref.kth_value(scores, k)
    return jnp.where(jnp.isfinite(tau), tau, -jnp.inf)


def query_sort_perm(qp: Array) -> Array:
    """Permutation grouping queries by nearest pivot (desc sim within group).

    The kernel paths skip a db tile only when *no* query in the BM-row
    tile needs it — angularly coherent query tiles are what let that OR
    fire.  Shared by the flat kernel backend and the tree backend's
    kernel leaf stage so the two paths can never diverge in grouping.
    """
    return jnp.lexsort((-jnp.max(qp, axis=1), jnp.argmax(qp, axis=1)))


def best_first_order(ub: Array) -> Array:
    """Blocks permuted by descending upper bound, aggregated over queries.

    ``ub`` [m, nb] -> [nb] i32 visiting order.  Aggregation is ``max`` over
    the query tile: the block *any* query still needs comes first, which is
    what drives every query's τ up fastest (DESIGN.md §3.2).
    """
    return jnp.argsort(-ub.max(axis=0)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# scan backend inner loop
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("k", "prune", "warm_start", "best_first", "element_stats",
                     "warm_start_blocks", "n_pivots"),
)
def scan_search(
    index: BlockIndex,
    qn: Array,
    qp: Array,
    k: int,
    *,
    prune: bool = True,
    margin: float = 4e-7,
    warm_start: bool = False,
    best_first: bool = False,
    element_stats: bool = False,
    warm_start_blocks: int | None = None,
    n_pivots: int = 0,
    tau0: Array | None = None,
    ub_all: Array | None = None,
    leaf_mask: Array | None = None,
    db_scratch: Array | None = None,
):
    """Pure-JAX block scan (the portable backend; DESIGN.md §2 for the block
    granularity, §3.3 for the backend contract this implements).

    Returns ``(top_s [m,k], pos [m,k] padded-row positions, blk_pruned,
    elem_pruned)`` — id mapping and stats normalization happen in the
    engine.  Pruned matmuls are computed-and-masked (XLA has no
    data-dependent skip); the kernel backend actually skips them.
    ``warm_start_blocks`` widens the τ prescan beyond the ``ceil(k / bs)``
    floor (DESIGN.md §3.4).  ``n_pivots`` > 0 intersects the joint
    multi-pivot projection cap into the block bound matrix before the
    scan (the ``eq13_multi`` provider, DESIGN.md §3.8) — it tightens the
    warm-start seed, the best-first order, and the per-block prune test.

    The three optional arrays let a hierarchical caller (the ``tree``
    backend, DESIGN.md §3.5) reuse this loop as its leaf stage: ``tau0``
    [m] overrides the internal τ warm-start seed (must be a true lower
    bound on each query's final k-th best, or -inf), ``ub_all`` [m, nb]
    supplies an already-computed block bound matrix (the descent's last
    level) so it is not re-evaluated here, and ``leaf_mask`` [m, nb] marks
    blocks a caller has *proven* prunable (mask False ⇒ skipped and
    counted in ``blk_pruned``; exactness is the caller's obligation).

    ``db_scratch`` [nb, bs, d] (``best_first`` only) is an engine-owned
    recycled buffer for the per-call best-first database permutation —
    the one large per-call allocation this loop makes.  When supplied,
    the permuted blocks are routed through it and returned as an extra
    trailing output, so a caller that donates the buffer (the engine's
    fused dispatch cache does) lets XLA write the gather in place and
    cycle the same memory call over call.
    """
    m = qn.shape[0]
    nb, bs = index.n_blocks, index.block_size
    db_blocks = index.db.reshape(nb, bs, -1)
    dp_blocks = index.dp.reshape(nb, bs, -1)
    valid_blocks = index.valid.reshape(nb, bs)
    base_idx = (jnp.arange(nb)[:, None] * bs
                + jnp.arange(bs)[None, :]).astype(jnp.int32)

    if ub_all is None and (warm_start or best_first
                           or (prune and n_pivots > 0)):
        ub_all = kref.block_bounds(qp, index.dp_min, index.dp_max)  # [m, nb]
    if prune and n_pivots > 0:
        # eq13_multi: intersect the joint n_pivots-deep projection cap —
        # min of valid upper bounds is a valid upper bound (DESIGN.md §3.8)
        ub_all = jnp.minimum(
            ub_all, multipivot_block_cap(index, qn, n_pivots=n_pivots))

    if tau0 is None:
        tau0 = jnp.full((m,), -jnp.inf, jnp.float32)
        if warm_start:
            n_pre = prescan_blocks(k, bs, nb, warm_start_blocks)
            tau0 = tau_warm_start(qn, db_blocks, valid_blocks, ub_all, k,
                                  n_pre)

    # when the bound matrix already exists (warm start / best-first / a tree
    # descent), feed it through the scan instead of re-evaluating Eq. 13 per
    # block
    reuse_ub = prune and ub_all is not None
    has_mask = leaf_mask is not None
    xs = (db_blocks, dp_blocks, valid_blocks, base_idx,
          index.dp_min, index.dp_max)
    if reuse_ub:
        xs = xs + (ub_all.T,)                                 # [nb, m]
    if has_mask:
        xs = xs + (leaf_mask.T,)                              # [nb, m]
    perm_db = None
    if best_first:
        order = best_first_order(ub_all)
        xs = tuple(a[order] for a in xs)
        if db_scratch is not None:
            # route the permuted db through the caller's scratch: the
            # .set is the gather's destination, so a donated buffer is
            # written in place instead of freshly allocated per call
            perm_db = db_scratch.at[:].set(xs[0])
            xs = (perm_db,) + xs[1:]

    init = (
        jnp.tile((tau0 - 1e-6)[:, None], (1, k)),             # seeded top sims
        jnp.full((m, k), -1, jnp.int32),                      # top positions
        jnp.zeros((), jnp.float32),                           # pruned pairs
        jnp.zeros((), jnp.float32),                           # prunable elems
    )

    def step(carry, x):
        top_s, top_i, blk_pruned, elem_pruned = carry
        blk, dpb, vb, bidx, lo, hi = x[:6]
        rest = x[6:]
        if reuse_ub:
            ub, rest = rest[0], rest[1:]                      # [m]
        else:
            ub = block_upper_bound(qp, lo, hi) if prune else None
        lmask = rest[0] if has_mask else None                 # [m] bool
        tau = top_s[:, -1]                                    # running kth best
        if prune:
            needed = ub + margin >= tau
        else:
            needed = jnp.ones((m,), bool)
        if has_mask:
            needed = needed & lmask
        scores = qn @ blk.T                                   # [m, bs]
        scores = jnp.where(vb[None, :], scores, -jnp.inf)
        scores = jnp.where(needed[:, None], scores, -jnp.inf)
        cand_s = jnp.concatenate([top_s, scores], axis=1)
        cand_i = jnp.concatenate(
            [top_i, jnp.broadcast_to(bidx[None, :], (m, bs))], axis=1)
        new_s, sel = jax.lax.top_k(cand_s, k)
        new_i = jnp.take_along_axis(cand_i, sel, axis=1)
        blk_pruned = blk_pruned + (~needed).sum().astype(jnp.float32)
        if element_stats:
            eub = jnp.min(ub_mult(qp[:, None, :], dpb[None, :, :]), axis=-1)
            elem_pruned = elem_pruned + (
                ((eub + margin < tau[:, None]) & vb[None, :])
                .sum().astype(jnp.float32))
        return (new_s, new_i, blk_pruned, elem_pruned), None

    (top_s, top_i, blk_pruned, elem_pruned), _ = jax.lax.scan(step, init, xs)
    if perm_db is not None:
        return top_s, top_i, blk_pruned, elem_pruned, perm_db
    return top_s, top_i, blk_pruned, elem_pruned


# ---------------------------------------------------------------------------
# kernel backend wrapper
# ---------------------------------------------------------------------------

def _resolve_bn(index: BlockIndex, bn: int | None) -> int:
    """Kernel tile size: a multiple of the index block size dividing n_pad."""
    n_pad = index.db.shape[0]
    ibs = index.block_size
    if bn is None:
        bn = ibs if ibs % 128 == 0 else ibs * max(1, -(-128 // ibs))
    while n_pad % bn or bn % ibs:
        bn //= 2
        if bn < ibs:
            bn = ibs
            break
    return bn


@functools.partial(
    jax.jit,
    static_argnames=("k", "bm", "bn", "prune", "sort_queries", "warm_start",
                     "best_first", "margin", "interpret", "element_stats",
                     "warm_start_blocks", "n_pivots"),
)
def kernel_search(
    index: BlockIndex,
    qn: Array,
    qp: Array,
    k: int,
    *,
    bm: int = cosine_topk.DEFAULT_BM,
    bn: int | None = None,
    prune: bool = True,
    sort_queries: bool = True,
    warm_start: bool = False,
    best_first: bool = False,
    margin: float = 4e-7,
    interpret: bool | None = None,
    element_stats: bool = False,
    warm_start_blocks: int | None = None,
    n_pivots: int = 0,
):
    """Fused Pallas backend (see :mod:`repro.kernels.cosine_topk`).

    Returns ``(sims [m,k], pos [m,k] padded-row positions, computed
    [m_tiles, n_tiles], elem_pruned)`` — ``elem_pruned`` is the [m_tiles,
    n_tiles] per-tile count of (query, row) pairs whose individual Eq. 13
    bound prunes them, or ``None`` unless ``element_stats``.
    ``sort_queries`` groups queries by nearest pivot so BM-row tiles are
    angularly coherent (the kernel prunes a db tile only when *no* query in
    the tile needs it); results are unsorted before returning.
    ``best_first`` hands the kernel a per-query-tile block visiting order
    (scalar-prefetched index map).  ``warm_start_blocks`` widens the τ
    prescan beyond ``ceil(k / bn)`` kernel tiles (DESIGN.md §3.4); the
    prescan granularity here is the *kernel tile* (bn rows), not the index
    block.  ``n_pivots`` > 0 computes the joint multi-pivot cap at index
    block granularity, coarsens it to kernel tiles (max over merged
    blocks — still a valid tile bound), and hands it to the kernel as the
    extra per-(query-tile, db-tile) bound operand.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bn = _resolve_bn(index, bn)
    factor = bn // index.block_size
    lo, hi = coarsen_intervals(index.dp_min, index.dp_max, factor)
    m = qn.shape[0]
    if sort_queries:
        perm = query_sort_perm(qp)
        qn, qp = qn[perm], qp[perm]
    n_valid = index.valid.sum().astype(jnp.int32)

    ub_cap = None
    if prune and n_pivots > 0:
        cap = multipivot_block_cap(index, qn, n_pivots=n_pivots)  # [m, nb]
        ub_cap = cap.reshape(m, lo.shape[0], -1).max(axis=-1)     # [m, nt]
    ub = None
    if warm_start or best_first:
        ub = kref.block_bounds(qp, lo, hi)                    # [m, n_tiles]
        if ub_cap is not None:
            ub = jnp.minimum(ub, ub_cap)
    tau_init = None
    if warm_start:
        db_tiles = index.db.reshape(-1, bn, index.db.shape[-1])
        valid_tiles = index.valid.reshape(-1, bn)
        n_pre = prescan_blocks(k, bn, db_tiles.shape[0], warm_start_blocks)
        tau_init = tau_warm_start(qn, db_tiles, valid_tiles, ub, k, n_pre)
    block_order = None
    if best_first:
        mp = -(-m // bm) * bm
        nt = lo.shape[0]
        ub_p = jnp.pad(ub, ((0, mp - m), (0, 0)), constant_values=-jnp.inf)
        tile_ub = ub_p.reshape(mp // bm, bm, nt).max(axis=1)  # [m_tiles, nt]
        block_order = jnp.argsort(-tile_ub, axis=1).astype(jnp.int32)

    sims, pos, computed, elem = cosine_topk.pruned_topk(
        qn, index.db, qp, lo, hi, n_valid,
        tau_init=tau_init, block_order=block_order,
        dp=index.dp if element_stats else None, ub_cap=ub_cap,
        row_valid=index.valid,
        k=k, bm=bm, bn=bn, margin=margin, prune=prune, interpret=interpret,
        element_stats=element_stats,
    )
    if sort_queries:
        inv = jnp.argsort(perm)
        sims, pos = sims[inv], pos[inv]
    return sims, pos, computed, elem


# ---------------------------------------------------------------------------
# brute backend inner
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k",))
def brute_search(index: BlockIndex, qn: Array, k: int):
    """Full matmul + top-k over the padded database (positions, not ids).

    ``k`` is clamped to the padded row count — ``lax.top_k`` rejects a k
    wider than its operand — and the tail pads with ``(-inf, -1)``, the
    same fill the ``search()`` contract documents for slots beyond the
    valid rows (and that the scan/tree loops produce naturally).  This
    matters here more than anywhere: ``auto_backend`` routes exactly the
    tiny datastores where ``k > n`` is most likely to brute.
    """
    scores = qn @ index.db.T
    scores = jnp.where(index.valid[None, :], scores, -jnp.inf)
    kk = min(k, scores.shape[-1])
    sims, pos = jax.lax.top_k(scores, kk)
    if kk < k:
        pad = ((0, 0), (0, k - kk))
        sims = jnp.pad(sims, pad, constant_values=-jnp.inf)
        pos = jnp.pad(pos, pad, constant_values=-1)
    return sims, pos.astype(jnp.int32)


# ---------------------------------------------------------------------------
# the registered backends
# ---------------------------------------------------------------------------

@register_backend("scan")
class ScanBackend:
    """Portable pure-JAX block scan."""

    name = "scan"

    def run(self, eng, queries, k, *, prune=True, element_stats=False):
        qn, qp = prep_queries(eng.index, queries)
        s, pos, blk_pruned, elem_pruned = scan_search(
            eng.index, qn, qp, k, prune=prune, margin=eng.margin,
            warm_start=eng.warm_start, best_first=eng.best_first,
            element_stats=element_stats,
            warm_start_blocks=eng.warm_start_blocks,
            n_pivots=eng.n_pivots)
        ids = map_row_ids(eng.index.row_ids, pos)
        m, nb = qn.shape[0], eng.index.n_blocks
        # raw stats stay jnp scalars: engine.search converts to host floats
        # only outside of tracing (lookup may run inside a decode jit)
        raw = {"block_prune_frac": blk_pruned / (m * nb)}
        if element_stats:
            raw["elem_prune_frac"] = elem_pruned / (m * max(1, eng.n_valid))
        return s, ids, raw

    def make_fused(self, eng, k, *, prune, element_stats, donate):
        """One-dispatch callee: prep + τ prescan + scan + id map, one jit.

        ``donate``: also thread the engine-owned best-first permutation
        scratch through the call (donated, cycled by the engine's cache
        entry) so the one large per-call buffer is written in place.
        """
        note = eng._note_trace
        margin, warm_start = eng.margin, eng.warm_start
        best_first, wsb = eng.best_first, eng.warm_start_blocks
        n_piv = eng.n_pivots

        def body(index, queries, scratch=None):
            note()          # Python side effect: fires at trace time only
            qn, qp = prep_queries(index, queries)
            out = scan_search(
                index, qn, qp, k, prune=prune, margin=margin,
                warm_start=warm_start, best_first=best_first,
                element_stats=element_stats, warm_start_blocks=wsb,
                n_pivots=n_piv, db_scratch=scratch)
            s, pos, blk_pruned, elem_pruned = out[:4]
            ids = map_row_ids(index.row_ids, pos)
            m, nb = qn.shape[0], index.n_blocks
            raw = {"block_prune_frac": blk_pruned / (m * nb)}
            if element_stats:
                # traced, not captured: online mutation changes the live
                # row count without retracing this callee
                n_valid = jnp.maximum(index.valid.sum(), 1)
                raw["elem_prune_frac"] = elem_pruned / (m * n_valid)
            if scratch is not None:
                return s, ids, raw, out[4]
            return s, ids, raw

        if donate and best_first:
            return jax.jit(body, donate_argnums=(2,))
        return jax.jit(lambda index, queries: body(index, queries))


@register_backend("kernel")
class KernelBackend:
    """Fused Pallas kernel (interpret mode off-TPU)."""

    name = "kernel"

    def run(self, eng, queries, k, *, prune=True, element_stats=False):
        qn, qp = prep_queries(eng.index, queries)
        s, pos, computed, elem = kernel_search(
            eng.index, qn, qp, k, bm=eng.bm, bn=eng.bn, prune=prune,
            sort_queries=eng.sort_queries, warm_start=eng.warm_start,
            best_first=eng.best_first, margin=eng.margin,
            interpret=eng.interpret, element_stats=element_stats,
            warm_start_blocks=eng.warm_start_blocks,
            n_pivots=eng.n_pivots)
        ids = map_row_ids(eng.index.row_ids, pos)
        frac = computed.mean()
        raw = {"block_prune_frac": 1.0 - frac, "tile_computed_frac": frac}
        if element_stats:
            m = qn.shape[0]
            raw["elem_prune_frac"] = (
                elem.astype(jnp.float32).sum() / (m * max(1, eng.n_valid)))
        return s, ids, raw

    def make_fused(self, eng, k, *, prune, element_stats, donate):
        """Prep + fused Pallas search + id map as one jitted dispatch."""
        note = eng._note_trace
        bm, bn, sq = eng.bm, eng.bn, eng.sort_queries
        warm_start, best_first = eng.warm_start, eng.best_first
        margin, interpret, wsb = eng.margin, eng.interpret, \
            eng.warm_start_blocks
        n_piv = eng.n_pivots

        @jax.jit
        def fused(index, queries):
            note()
            qn, qp = prep_queries(index, queries)
            s, pos, computed, elem = kernel_search(
                index, qn, qp, k, bm=bm, bn=bn, prune=prune,
                sort_queries=sq, warm_start=warm_start,
                best_first=best_first, margin=margin, interpret=interpret,
                element_stats=element_stats, warm_start_blocks=wsb,
                n_pivots=n_piv)
            ids = map_row_ids(index.row_ids, pos)
            frac = computed.mean()
            raw = {"block_prune_frac": 1.0 - frac,
                   "tile_computed_frac": frac}
            if element_stats:
                m = qn.shape[0]
                n_valid = jnp.maximum(index.valid.sum(), 1)  # traced: online
                raw["elem_prune_frac"] = (
                    elem.astype(jnp.float32).sum() / (m * n_valid))
            return s, ids, raw

        return fused


@register_backend("brute")
class BruteBackend:
    """Exact baseline: one big matmul, no pruning."""

    name = "brute"

    def run(self, eng, queries, k, *, prune=True, element_stats=False):
        qn, _ = prep_queries(eng.index, queries)
        s, pos = brute_search(eng.index, qn, k)
        ids = map_row_ids(eng.index.row_ids, pos)
        raw = {"block_prune_frac": 0.0}
        if element_stats:
            # brute force evaluates no bounds and skips nothing — the
            # element pruning fraction is 0 by definition (glossary in
            # docs/search-api.md)
            raw["elem_prune_frac"] = 0.0
        return s, ids, raw

    def make_fused(self, eng, k, *, prune, element_stats, donate):
        """Prep + matmul + top-k + id map as one jitted dispatch."""
        note = eng._note_trace

        @jax.jit
        def fused(index, queries):
            note()
            qn, _ = prep_queries(index, queries)
            s, pos = brute_search(index, qn, k)
            ids = map_row_ids(index.row_ids, pos)
            raw = {"block_prune_frac": 0.0}
            if element_stats:
                raw["elem_prune_frac"] = 0.0
            return s, ids, raw

        return fused


@register_backend("sharded")
class ShardedBackend:
    """Mesh-sharded scan + all-gather top-k merge (needs ``mesh``).

    With ``SearchEngine(tree_shards=...)`` enabled, each shard first runs
    the transitive Eq. 13 descent over its own pivot tree (built lazily
    here, one tree per shard, placed like the index so every device holds
    only its own) pruning against the broadcast global τ; the surviving
    leaves feed the same per-shard scan loop — DESIGN.md §3.6.  The
    descent runs *inside* ``shard_map`` with fully static shapes, so the
    whole path stays one jitted unit.
    """

    name = "sharded"

    def _shard_tree(self, eng):
        tree = eng._shard_tree
        if tree is None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.search.tree import ShardTreeArrays, build_shard_trees
            axis = tuple(eng.axis_names or eng.mesh.axis_names)
            sh = NamedSharding(eng.mesh, P(axis))
            # built under jit with explicit out_shardings (not eagerly +
            # device_put): each device computes only its own shard's tree,
            # and a multi-host index — whose leaves are not addressable
            # outside jit — stays legal input
            build = jax.jit(build_shard_trees,
                            out_shardings=ShardTreeArrays(sh, sh, sh))
            tree = build(eng.index)
            eng._shard_tree = tree
        return tree

    def _replicated_queries(self, eng, queries):
        """Queries as the replicated operand the sharded closure expects.

        Single-process (or under an outer trace) this is a plain
        ``jnp.asarray``; on a multi-process mesh every host passes the
        same batch and it becomes one fully-replicated global array —
        required by ``jit`` when the mesh spans processes.
        """
        q = queries
        if isinstance(q, jax.Array) and not q.is_fully_addressable:
            return q                      # already a global (multi-host) array
        if jax.process_count() > 1 and not isinstance(q, jax.core.Tracer):
            import numpy as _np

            from repro.dist.compat import replicate_to_mesh
            return replicate_to_mesh(_np.asarray(q, _np.float32), eng.mesh)
        return jnp.asarray(q, jnp.float32)

    def run(self, eng, queries, k, *, prune=True, element_stats=False):
        if eng.mesh is None:
            raise ValueError("the 'sharded' backend needs SearchEngine(mesh=...)")
        # the descent is pure masking work with prune off: fall back to the
        # flat per-shard scan, which honors prune=False like every backend
        use_tree = eng._tree_shards_enabled and prune
        key = (element_stats, use_tree, prune, eng.n_pivots)
        fn = eng._sharded_fn.get(key)
        if fn is None:
            from repro.core.distributed import make_sharded_search
            fn = make_sharded_search(
                eng.mesh, eng.axis_names, with_stats=True, prune=prune,
                warm_start=eng.warm_start, best_first=eng.best_first,
                warm_start_blocks=eng.warm_start_blocks,
                element_stats=element_stats, margin=eng.margin,
                n_pivots=eng.n_pivots,
                trace_hook=eng._note_trace)
            eng._sharded_fn[key] = fn
        q = self._replicated_queries(eng, queries)
        if use_tree:
            s, ids, frac, efrac, tfrac, evfrac = fn(
                eng.index, q, k, self._shard_tree(eng))
            raw = {"block_prune_frac": frac, "tree_prune_frac": tfrac,
                   "tree_node_eval_frac": evfrac}
        else:
            s, ids, frac, efrac = fn(eng.index, q, k)
            raw = {"block_prune_frac": frac}
        if element_stats:
            raw["elem_prune_frac"] = efrac
        return s, ids, raw


# the tree backend lives in its own module (it is a subsystem, not an inner
# loop) but registers here; importing it last keeps the registry complete for
# callers that import repro.search.backends directly.  Safe despite the cycle:
# this module is fully defined by the time the import runs.
from repro.search import tree as _tree  # noqa: E402,F401  (registration)
