"""The train step: loss -> grads -> (optional compression) -> AdamW.

Supports microbatch gradient accumulation (``accum`` splits the per-call
batch along batch dim and scans, summing grads) — the standard way to hit
global batch 256 x 4k tokens within HBM.  The whole step is one jittable
function of (params, opt_state, batch, step) so pjit shards everything via
in/out shardings chosen by the launcher.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import ModelFns
from repro.optim import adamw, compression, schedule
from repro.train.losses import chunked_ce


def make_loss_fn(fns: ModelFns, cfg: ModelConfig, *, aux_weight: float = 0.01,
                 cast_bf16: bool = False):
    """``cast_bf16``: cast fp32 matrices to bf16 ONCE at loss entry (mixed
    precision — fp32 master copies stay in the optimizer).  Halves the
    parameter bytes read per layer and, under FSDP, halves the parameter
    all-gather payload (§Perf.P1)."""
    def loss_fn(params, batch):
        if cast_bf16:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if (p.dtype == jnp.float32 and p.ndim >= 2) else p, params)
        hidden, _, aux = fns.forward(params, batch)
        off = fns.loss_offset(batch)
        labels = batch["labels"]
        if off:
            # prefix positions (vision/audio) carry no next-token loss
            hidden = hidden[:, off:]
        head = lambda h: fns.lm_head(params, h)
        loss, metrics = chunked_ce(hidden, labels, head, cfg)
        loss = loss + aux_weight * aux
        metrics["aux"] = aux
        return loss, metrics
    return loss_fn


def make_train_step(
    fns: ModelFns,
    cfg: ModelConfig,
    *,
    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
    lr_schedule=functools.partial(schedule.warmup_cosine, peak_lr=3e-4,
                                  warmup_steps=100, total_steps=10000),
    accum: int = 1,
    compress_grads: bool = False,
):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "step", ["err"]}
    """
    loss_fn = make_loss_fn(fns, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]

        if accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                (loss, metrics), g = grad_fn(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), metrics

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mb = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            (gsum, lsum), ms = jax.lax.scan(micro, (zeros, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = jax.tree.map(lambda m: m[-1], ms)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if compress_grads:
            grads, new_err = compression.compress_tree(grads, state["err"])

        lr = lr_schedule(state["opt"]["step"])
        new_params, new_opt, opt_metrics = adamw.update(
            grads, state["opt"], params, lr, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if compress_grads:
            new_state["err"] = new_err
        return new_state, metrics

    return train_step


def init_state(fns: ModelFns, key, *, compress_grads: bool = False,
               abstract: bool = False):
    def build(k):
        params = fns.init(k)
        st = {"params": params, "opt": adamw.init(params),
              "step": jnp.zeros((), jnp.int32)}
        if compress_grads:
            st["err"] = compression.init_error(params)
        return st
    if abstract:
        return jax.eval_shape(build, key)
    return build(key)
