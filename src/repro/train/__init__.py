"""subpackage."""
