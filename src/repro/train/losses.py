"""Chunked cross-entropy: never materializes the [B, S, V] logits tensor.

With V up to 152k and S up to 32k, full logits are the single largest
activation in the model (orders of magnitude over everything else).  The
loss therefore scans over sequence chunks of ``cfg.logits_chunk`` tokens:
per chunk, project to logits (fp32), log-softmax, gather the label
log-probs, accumulate (sum_nll, count).  ``jax.checkpoint`` on the chunk
body makes backward recompute the chunk logits instead of storing them.

Also provides z-loss (softmax normalizer regularization, Chowdhery et al.)
— standard for large-vocab stability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.models.config import ModelConfig


def chunked_ce(hidden: Array, labels: Array, head_fn, cfg: ModelConfig, *,
               mask: Array | None = None, z_weight: float = 1e-4):
    """hidden [B,S,D], labels [B,S] -> (mean_nll, metrics).

    ``head_fn(hidden_chunk) -> logits_chunk`` (fp32).  ``mask`` [B,S] in
    {0,1} excludes positions (padding / vision prefix) from the loss.
    """
    B, S, D = hidden.shape
    c = min(cfg.logits_chunk, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None else jnp.ones((B, S), jnp.float32),
                       ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    nchunk = hidden.shape[1] // c
    hs = jnp.moveaxis(hidden.reshape(B, nchunk, c, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nchunk, c), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, nchunk, c), 1, 0)

    def body(carry, xs):
        nll_sum, z_sum, n = carry
        h, l, m = xs
        logits = head_fn(h).astype(jnp.float32)              # [B,c,V]
        lse = jax.nn.logsumexp(logits, axis=-1)              # [B,c]
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        z = jnp.square(lse) * m
        return (nll_sum + nll.sum(), z_sum + z.sum(), n + m.sum()), None

    body = jax.checkpoint(body)
    (nll_sum, z_sum, n), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32),) * 3, (hs, ls, ms))
    n = jnp.maximum(n, 1.0)
    loss = nll_sum / n + z_weight * z_sum / n
    metrics = {"nll": nll_sum / n, "zloss": z_sum / n, "tokens": n}
    return loss, metrics
