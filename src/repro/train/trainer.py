"""Training loop with checkpoint/restart, straggler watchdog, preemption.

Fault-tolerance model (what actually happens at 1000+ nodes):

* **checkpoint/restart** — CheckpointManager snapshots (params, opt, step,
  data state) every ``ckpt_every`` steps asynchronously; on start the
  trainer restores the latest complete checkpoint, so any crash loses at
  most ``ckpt_every`` steps.
* **preemption** — SIGTERM sets a flag; the loop finishes the in-flight
  step, writes a blocking checkpoint and exits 0 (the scheduler restarts
  the job elsewhere).
* **straggler watchdog** — per-step wall time is tracked with an EMA; steps
  slower than ``straggler_factor`` x EMA are counted and logged with their
  step index (on a fleet this feeds the hot-spare swap decision; here it is
  surfaced in metrics and tested by injecting a slow step).
* **elastic restart** — restore() accepts a different mesh: the checkpoint
  stores full (unsharded) arrays, and `repro.dist.elastic.remesh` picks the
  largest usable mesh from the surviving devices, onto which restore
  re-device_puts (tested with a shrunken CPU mesh).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ema_alpha: float = 0.1


class Trainer:
    def __init__(self, train_step: Callable, state, data_source,
                 cfg: TrainerConfig, *, make_global=None, hooks=()):
        self.train_step = train_step
        self.state = state
        self.data = data_source
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.make_global = make_global or (lambda b: jax.tree.map(
            jax.numpy.asarray, b))
        self.hooks = list(hooks)
        self._preempted = False
        self._ema = None
        self.straggler_steps: list[int] = []
        self.history: list[dict] = []

    def _handle_preempt(self, *_):
        self._preempted = True

    def maybe_restore(self) -> int:
        step = self.ckpt.latest_step()
        if step is None:
            return 0
        self.state, extra, step = self.ckpt.restore(self.state, step)
        if "data" in extra:
            self.data.restore(extra["data"])
        return int(step)

    def run(self, *, install_signal: bool = True) -> dict:
        if install_signal:
            try:
                signal.signal(signal.SIGTERM, self._handle_preempt)
            except ValueError:
                pass  # not main thread
        start = self.maybe_restore()
        step = start
        while step < self.cfg.total_steps and not self._preempted:
            batch = self.make_global(self.data.batch(step))
            t0 = time.perf_counter()
            self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            # straggler watchdog
            if self._ema is None:
                self._ema = dt
            else:
                if dt > self.cfg.straggler_factor * self._ema and step > start + 2:
                    self.straggler_steps.append(step)
                self._ema = (1 - self.cfg.ema_alpha) * self._ema + \
                    self.cfg.ema_alpha * dt
            step += 1
            rec = {"step": step, "time_s": dt,
                   **{k: float(np.asarray(v)) for k, v in metrics.items()}}
            self.history.append(rec)
            for h in self.hooks:
                h(step, self.state, rec)
            if step % self.cfg.log_every == 0 or step == self.cfg.total_steps:
                print(f"step {step:6d} loss {rec['loss']:.4f} "
                      f"({dt*1e3:.0f} ms, grad_norm {rec.get('grad_norm', 0):.2f})",
                      flush=True)
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save(step, self.state,
                               extra={"data": self.data.state()})
        # final/preemption checkpoint is synchronous
        self.ckpt.save(step, self.state, extra={"data": self.data.state()},
                       block=True)
        return {"final_step": step, "preempted": self._preempted,
                "stragglers": self.straggler_steps, "history": self.history}
