"""Assigned input shapes and abstract input specs for the dry-run.

Four shapes per architecture (LM family):

  train_4k      seq 4096   global_batch 256   -> train_step
  prefill_32k   seq 32768  global_batch 32    -> serve prefill
  decode_32k    seq 32768  global_batch 128   -> serve_step (1 new token,
                                                 KV/state cache of 32k)
  long_500k     seq 524288 global_batch 1     -> serve_step; ONLY for
                sub-quadratic archs (SSM/hybrid/SWA) — full-attention archs
                skip it (DESIGN.md §5)

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins (no allocation);
modality frontends are stubs, so whisper gets frame *embeddings* and
internvl2 gets patch *embeddings* directly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def model_kind(cfg: ModelConfig) -> str:
    if cfg.encoder_layers > 0:
        return "whisper"
    if cfg.vision_seq > 0:
        return "vlm"
    return "lm"


def is_subquadratic(cfg: ModelConfig) -> bool:
    """True if the arch can run long_500k (SSM/hybrid/SWA-bounded)."""
    types = set(cfg.layer_types)
    if types <= {"mamba2", "rwkv6", "shared_attn"} and (
            "mamba2" in types or "rwkv6" in types):
        return cfg.sliding_window is not None or "shared_attn" not in types
    return cfg.sliding_window is not None


def applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not is_subquadratic(cfg):
        return False, "full attention is quadratic/unbounded-KV at 500k"
    return True, ""


def input_specs(cfg: ModelConfig, shape: Shape, *, scale: float = 1.0) -> dict:
    """Abstract inputs for the given cell.  ``scale`` shrinks batch for
    smoke tests (batch >= 1)."""
    from repro.models.vlm import VIT_WIDTH

    b = max(1, int(shape.batch * scale))
    s = shape.seq
    i32 = jnp.int32
    kind = model_kind(cfg)
    f = jax.ShapeDtypeStruct

    if shape.kind == "train":
        specs = {
            "tokens": f((b, s), i32),
            "labels": f((b, s), i32),
        }
        if kind == "vlm":
            specs["patches"] = f((b, cfg.vision_seq, VIT_WIDTH), jnp.bfloat16)
        if kind == "whisper":
            specs["frames"] = f((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": f((b, s), i32)}
        if kind == "vlm":
            specs["patches"] = f((b, cfg.vision_seq, VIT_WIDTH), jnp.bfloat16)
        if kind == "whisper":
            specs["frames"] = f((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token against a cache of length seq
    specs = {
        "tokens": f((b, 1), i32),
        "cache_len": f((), i32),
    }
    return specs
