"""Configuration of the paper's search subsystem itself.

These defaults reflect the §Perf.P3 hillclimb (EXPERIMENTS.md): 16 max-min
pivots, 128-row blocks (MXU-aligned), angular reorder on, query sorting on,
tau warm-start on, bm=32 query tiles (TPU sublane-friendly middle of the
16–64 sweet spot measured in interpret mode).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    n_pivots: int = 16
    block_size: int = 128
    pivot_method: str = "maxmin"
    reorder: bool = True
    # kernel search params
    bm: int = 32
    sort_queries: bool = True
    warm_start: bool = True
    margin: float = 4e-7
    # serving
    k: int = 16
    knn_temp: float = 10.0
    knn_lambda: float = 0.25


DEFAULT = IndexConfig()
