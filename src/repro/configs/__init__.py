"""Architecture configs (one per assigned arch) + input shapes."""
from repro.configs.archs import ARCHS, smoke_config  # noqa: F401
from repro.configs.shapes import SHAPES, Shape, applicable, input_specs, model_kind  # noqa: F401
