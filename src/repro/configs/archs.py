"""The 10 assigned architectures — exact configs from the assignment table.

Each entry also defines a REDUCED smoke config of the same family (small
width/depth, tiny vocab) used by per-arch CPU smoke tests; the full configs
are exercised only through the dry-run (abstract shapes, no allocation).

Sources are cited per config ([arXiv/hf] tags from the assignment).
"""
from __future__ import annotations

from repro.models.config import ModelConfig, MoEConfig, SSMConfig

# ---------------------------------------------------------------------------
# full configs
# ---------------------------------------------------------------------------

MIXTRAL_8X22B = ModelConfig(                     # [arXiv:2401.04088; hf]
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=32768, block_pattern=("moe",),
    moe=MoEConfig(n_experts=8, top_k=2),
    sliding_window=4096,                         # SWA per assignment
    rope_theta=1e6, max_seq_len=65536,
)

GRANITE_MOE_1B = ModelConfig(                    # [hf:ibm-granite/granite-3.0-1b-a400m-base]
    name="granite-moe-1b-a400m",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab=49155, block_pattern=("moe",),
    moe=MoEConfig(n_experts=32, top_k=8),
    tie_embeddings=True, rope_theta=10000.0,
)

TINYLLAMA_1B = ModelConfig(                      # [arXiv:2401.02385; hf]
    name="tinyllama-1.1b",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=5632,
    vocab=32000, block_pattern=("attn",),
)

GRANITE_3_2B = ModelConfig(                      # [hf:ibm-granite/granite-3.0-2b-base]
    name="granite-3-2b",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab=49155, block_pattern=("attn",), tie_embeddings=True,
)

QWEN2_5_14B = ModelConfig(                       # [hf:Qwen/Qwen2.5-14B]
    name="qwen2.5-14b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab=152064, block_pattern=("attn",), qkv_bias=True,
    rope_theta=1e6,
)

QWEN2_72B = ModelConfig(                         # [arXiv:2407.10671]
    name="qwen2-72b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, block_pattern=("attn",), qkv_bias=True,
    rope_theta=1e6,
)

ZAMBA2_1B = ModelConfig(                         # [arXiv:2411.15242]
    name="zamba2-1.2b",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000,
    # Mamba2 backbone; weight-tied shared attention every 6th layer
    block_pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2",
                   "shared_attn"),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1),
    sliding_window=4096,   # bounds shared-attn KV for the 500k cell
)

INTERNVL2_1B = ModelConfig(                      # [arXiv:2404.16821]
    name="internvl2-1b",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151655, block_pattern=("attn",), qkv_bias=True,
    tie_embeddings=True, vision_seq=256, rope_theta=1e6,
)

WHISPER_SMALL = ModelConfig(                     # [arXiv:2212.04356]
    name="whisper-small",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, block_pattern=("attn",), mlp_kind="gelu",
    norm_kind="layernorm", encoder_layers=12, encoder_seq=1500,
)

RWKV6_1B6 = ModelConfig(                         # [arXiv:2404.05892]
    name="rwkv6-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
    vocab=65536, block_pattern=("rwkv6",),
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        MIXTRAL_8X22B, GRANITE_MOE_1B, TINYLLAMA_1B, GRANITE_3_2B,
        QWEN2_5_14B, QWEN2_72B, ZAMBA2_1B, INTERNVL2_1B, WHISPER_SMALL,
        RWKV6_1B6,
    ]
}


# ---------------------------------------------------------------------------
# reduced smoke configs (same family, tiny dims, CPU-runnable)
# ---------------------------------------------------------------------------

def smoke_config(arch_id: str) -> ModelConfig:
    full = ARCHS[arch_id]
    kw = dict(
        n_layers=min(full.n_layers, 4),
        d_model=64, n_heads=4,
        n_kv_heads=min(4, max(1, full.n_kv_heads * 4 // full.n_heads)),
        d_head=16,
        d_ff=128, vocab=128, max_seq_len=128,
        attn_chunk_q=32, attn_chunk_k=32, logits_chunk=32,
        dtype="float32", use_scan=full.use_scan, remat=False,
        rope_theta=10000.0,
    )
    if full.moe is not None:
        kw["moe"] = MoEConfig(n_experts=min(8, full.moe.n_experts),
                              top_k=min(2, full.moe.top_k))
    if full.ssm is not None:
        kw["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2,
                              n_groups=1, chunk=16)
    if full.sliding_window is not None:
        kw["sliding_window"] = 64
    if full.encoder_layers:
        kw["encoder_layers"] = 2
        kw["encoder_seq"] = 24
    if full.vision_seq:
        kw["vision_seq"] = 8
    if "shared_attn" in full.layer_types:
        kw["n_layers"] = 6   # keep one shared block in the pattern
    return full.replace(**kw)
